//! The worker engine: denoising step loop with continuous batching,
//! mask-aware cached inference and the bubble-free load pipeline.
//!
//! One worker = one "GPU replica": an engine thread running the step loop,
//! a cache-loader thread (the copy stream), and — in disaggregated mode —
//! a small pre/post-processing pool. All four baselines of §6 are modes of
//! this engine (`SystemKind`), so the comparisons isolate exactly the
//! paper's design axes:
//!
//! - `InstGenIE`   mask-aware cached blocks + Algo-1 pipeline + step-level
//!                 continuous batching + disaggregated pre/post.
//! - `Diffusers`   full-image recompute, static batching.
//! - `FisEdit`     mask-aware compute with GPU-resident activations (free
//!                 loads) but batch = 1 and no continuous batching.
//! - `TeaCache`    full-image recompute with timestep-gated step skipping,
//!                 static batching.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use crate::cache::device::{KvDeviceTier, KvKey};
use crate::cache::loader::{CacheLoader, MemberGather, StagedBlock};
use crate::cache::pipeline::{self, PipelinePlan, PlanCache};
use crate::cache::store::{register_template, TemplateActivations};
use crate::cache::tier::{Residency, TieredStore};
use crate::cache::LatencyModel;
use crate::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
use crate::durable::{load_checkpoint, remove_checkpoint, request_checksum, save_checkpoint};
use crate::engine::prepost::{postprocess, preprocess, PreparedRequest};
use crate::engine::queue::{QueuePolicy, Submitter, WorkerQueue};
use crate::engine::request::{EditError, EditResponse, RequestTiming, WorkerEvent};
use crate::engine::teacache::TeaCacheGate;
use crate::faults::{FaultInjector, FaultSite};
use crate::model::{Latent, Schedule};
use crate::qos::{ClassDepth, Priority, CLASS_COUNT};
use crate::runtime::{ArtifactKind, ModelRuntime, TransferTotals};
use crate::templates::{TemplateRegistry, TemplateState};
use crate::util::pool::ThreadPool;
use crate::util::tensor::Tensor;

/// An in-flight batch member.
struct Member {
    prep: PreparedRequest,
    acts: Arc<TemplateActivations>,
    latent: Latent,
    step: usize,
    joined: Instant,
    interruptions: u32,
    steps_computed: u32,
    /// Cached compute-set ids Arc for loader jobs (avoids re-allocating
    /// the suffix id vector per block).
    cached_ids: Arc<Vec<usize>>,
    cached_bucket: usize,
    /// TeaCache: replayed eps (full (L, H)) + gate.
    last_eps: Option<Vec<f32>>,
    gate: Option<TeaCacheGate>,
    /// Times this member was preempted for an `Interactive` request (at
    /// most once, so preemption cannot thrash a member forever).
    preemptions: u32,
}

impl Member {
    fn rank(&self) -> usize {
        self.prep.request.priority.rank()
    }
}

/// Step-scoped scratch arena: every host buffer the hot loop touches,
/// allocated once and reused across steps. `grows` counts capacity
/// growths — once the engine has seen a shape, repeating it must not
/// grow anything (property-tested), so the steady-state step loop is
/// allocation-free on the coordinator side.
#[derive(Default)]
struct StepScratch {
    /// (bb, n, H) packed compute rows.
    packed: Vec<f32>,
    /// (bb, L, H) full-sequence batch input.
    full: Vec<f32>,
    /// Final block-chain output readback.
    out: Vec<f32>,
    /// Per-member full (L, H) hidden buffers.
    hidden: Vec<Vec<f32>>,
    /// TeaCache per-member compute gates.
    compute: Vec<bool>,
    /// Capacity-growth counter (see struct docs).
    grows: usize,
}

impl StepScratch {
    /// Resize a scratch buffer, counting capacity growth. Contents are
    /// unspecified afterwards — every user overwrites its slice fully.
    fn resize_tracked(v: &mut Vec<f32>, len: usize, grows: &mut usize) {
        if v.capacity() < len {
            *grows += 1;
        }
        v.resize(len, 0.0);
    }

    /// Pack each batch slot's bucket-`n` compute rows from the member
    /// hiddens into `packed` (padding slots replicate the last member).
    /// The single packing routine shared by the device chain and its
    /// host reference, so the two provably pack identically.
    fn pack_compute_rows(&mut self, members: &[Member], n: usize, h: usize, bb: usize) {
        let b = members.len();
        let StepScratch { packed, hidden, grows, .. } = self;
        StepScratch::resize_tracked(packed, bb * n * h, grows);
        for i in 0..bb {
            let mi = i.min(b - 1);
            let ids = members[mi].prep.perm.compute_ids(n);
            gather_rows(&hidden[mi], h, ids, &mut packed[i * n * h..(i + 1) * n * h]);
        }
    }

    /// Pack the full (L, H) member hiddens into `full` with the same
    /// last-member padding rule.
    fn pack_full_rows(&mut self, b: usize, l: usize, h: usize, bb: usize) {
        let StepScratch { full, hidden, grows, .. } = self;
        StepScratch::resize_tracked(full, bb * l * h, grows);
        for i in 0..bb {
            let mi = i.min(b - 1);
            full[i * l * h..(i + 1) * l * h].copy_from_slice(&hidden[mi]);
        }
    }
}

/// A popped request whose template is still registering cluster-wide: it
/// waits here — off the queue, so other templates' requests flow past —
/// until the registry publishes the template or the deadline passes
/// (submit-during-registration queues until ready or times out).
struct Parked {
    prep: PreparedRequest,
    deadline: Instant,
}

/// Admission decision for a popped request's template.
enum TemplateGate {
    /// Resident (or cold-registrable): admit now.
    Ready,
    /// Registration in flight: park the request.
    Pending,
    /// Typed terminal refusal (retired / failed registration).
    Refused(EditError),
}

/// Live load/state snapshot for the cluster scheduler (§4.4).
#[derive(Debug, Clone, Default)]
pub struct WorkerSnapshot {
    pub worker_id: usize,
    pub queued: usize,
    pub running: usize,
    /// Sum over queued+running requests of masked-token counts.
    pub queued_masked_tokens: usize,
    /// Mask ratios of queued + running requests (scheduler cost model).
    pub mask_ratios: Vec<f64>,
    /// Per-class queue depth + oldest-wait age (QoS observability).
    pub class_depths: [ClassDepth; CLASS_COUNT],
    /// Denoise steps this worker has executed so far.
    pub steps_executed: usize,
    /// Cumulative step-loop host<->device activation traffic.
    pub transfers: TransferTotals,
    /// Interactive editing sessions homed on this worker (overlaid by the
    /// session plane — workers themselves are session-blind).
    pub sessions_open: usize,
    /// Session rounds currently in flight (queued or running) here.
    pub session_rounds: usize,
}

impl WorkerSnapshot {
    /// Assemble a snapshot from the live handles (queue + engine-published
    /// shared state) — the cluster uses this after workers have started,
    /// when the `Worker` itself is owned by its thread.
    pub fn collect(
        worker_id: usize,
        queue: &WorkerQueue,
        shared: &WorkerShared,
    ) -> WorkerSnapshot {
        let mut mask_ratios = queue.queued_mask_ratios();
        mask_ratios.extend(shared.running_mask_ratios());
        WorkerSnapshot {
            worker_id,
            queued: queue.pending(),
            running: shared.running.load(Ordering::Relaxed),
            queued_masked_tokens: shared.running_masked.load(Ordering::Relaxed),
            mask_ratios,
            class_depths: queue.class_depths(Instant::now()),
            steps_executed: shared.steps_executed(),
            transfers: shared.transfers(),
            sessions_open: 0,
            session_rounds: 0,
        }
    }
}

/// One step-boundary progress report of a session round, streamed to SSE
/// clients. `seq` is a per-round monotone cursor so a reconnecting (or
/// slow) consumer can resume without duplicates after drop-oldest
/// backpressure.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgressEvent {
    pub seq: u64,
    /// Denoise steps completed so far (monotone within a round).
    pub step: u32,
    pub steps_total: u32,
    /// Estimated remaining latency in ms: the Algo-2 per-step cost
    /// (calibrated regressions + pipeline DP) times the remaining steps.
    pub est_remaining_ms: u64,
    /// Preview stats of the round's current latent (cheap client-side
    /// progress visualization without shipping the tensor).
    pub latent_mean: f32,
    pub latent_rms: f32,
    /// Terminal marker: the round left the engine; no further events.
    pub done: bool,
}

/// Most buffered events per round; older ones are dropped first, so a
/// slow SSE consumer loses history but never blocks the engine.
const PROGRESS_EVENT_CAP: usize = 64;
/// Terminal round buffers retained for late/reconnecting readers; beyond
/// this, the oldest finished round's buffer is dropped (no leak when no
/// client ever attaches).
const PROGRESS_DONE_KEEP: usize = 32;

#[derive(Default)]
struct RoundProgress {
    next_seq: u64,
    events: VecDeque<ProgressEvent>,
    done: bool,
}

#[derive(Default)]
struct ProgressBook {
    rounds: HashMap<u64, RoundProgress>,
    /// Terminal rounds in completion order (bounded retention).
    done_order: VecDeque<u64>,
}

/// Shared mutable state published by the engine thread.
#[derive(Default)]
pub struct WorkerShared {
    running: AtomicUsize,
    running_masked: AtomicUsize,
    steps_executed: AtomicUsize,
    /// Mask ratios of the running batch (Algo-2 cost model input).
    running_ratios: Mutex<Vec<f64>>,
    /// Step-loop transfer totals mirrored from the worker's runtime
    /// (the runtime itself is confined to the engine thread).
    h2d_ops: AtomicU64,
    d2h_ops: AtomicU64,
    h2d_bytes: AtomicU64,
    d2h_bytes: AtomicU64,
    kv_h2d_bytes: AtomicU64,
    kv_dev_hits: AtomicU64,
    kv_dev_misses: AtomicU64,
    kv_prefetch_overlap_us: AtomicU64,
    /// Degradation-ladder counters (see `TransferTotals`): disk-tier
    /// promotions demoted to recompute, device-KV uploads demoted to
    /// per-step staging, loader jobs demoted to synchronous gathers.
    cache_degraded_disk: AtomicU64,
    cache_degraded_device: AtomicU64,
    cache_degraded_loader: AtomicU64,
    /// Template ids whose device-KV entries must be dropped — pushed by
    /// cluster retirement (any thread), drained by the engine thread at
    /// loop boundaries (the tier itself is engine-thread-confined).
    kv_purges: Mutex<Vec<String>>,
    /// Per-round bounded progress-event buffers: pushed by the engine
    /// thread at step boundaries, drained by SSE handler threads.
    progress: Mutex<ProgressBook>,
}

impl WorkerShared {
    pub fn steps_executed(&self) -> usize {
        self.steps_executed.load(Ordering::Relaxed)
    }

    pub fn running_mask_ratios(&self) -> Vec<f64> {
        self.running_ratios.lock().unwrap().clone()
    }

    /// Ask the engine thread to drop a retired template's device-KV
    /// entries at its next loop boundary (the device tier mirrors the
    /// host/disk tiers' retirement purge, but cannot be touched from
    /// this thread).
    pub fn request_kv_purge(&self, template_id: &str) {
        self.kv_purges.lock().unwrap().push(template_id.to_string());
    }

    fn drain_kv_purges(&self) -> Vec<String> {
        std::mem::take(&mut *self.kv_purges.lock().unwrap())
    }

    /// Append a step-progress event for session round (request) `id`.
    /// When the bounded per-round buffer is full the *oldest* event is
    /// dropped — a slow or absent SSE consumer can never block or grow
    /// the engine step loop.
    #[allow(clippy::too_many_arguments)]
    pub fn push_progress(
        &self,
        id: u64,
        step: u32,
        steps_total: u32,
        est_remaining_ms: u64,
        latent_mean: f32,
        latent_rms: f32,
    ) {
        let mut book = self.progress.lock().unwrap();
        let r = book.rounds.entry(id).or_default();
        if r.done {
            return;
        }
        let seq = r.next_seq;
        r.next_seq += 1;
        if r.events.len() >= PROGRESS_EVENT_CAP {
            r.events.pop_front();
        }
        r.events.push_back(ProgressEvent {
            seq,
            step,
            steps_total,
            est_remaining_ms,
            latent_mean,
            latent_rms,
            done: false,
        });
    }

    /// Publish the terminal completion event for round `id` and bound the
    /// retained terminal buffers (oldest finished rounds are dropped so
    /// unwatched rounds cannot leak memory).
    pub fn finish_progress(&self, id: u64) {
        let mut book = self.progress.lock().unwrap();
        let r = book.rounds.entry(id).or_default();
        if !r.done {
            let seq = r.next_seq;
            r.next_seq += 1;
            let (step, steps_total) =
                r.events.back().map(|e| (e.step, e.steps_total)).unwrap_or((0, 0));
            if r.events.len() >= PROGRESS_EVENT_CAP {
                r.events.pop_front();
            }
            r.events.push_back(ProgressEvent {
                seq,
                step,
                steps_total,
                est_remaining_ms: 0,
                latent_mean: 0.0,
                latent_rms: 0.0,
                done: true,
            });
            r.done = true;
            book.done_order.push_back(id);
            while book.done_order.len() > PROGRESS_DONE_KEEP {
                match book.done_order.pop_front() {
                    Some(old) => book.rounds.remove(&old),
                    None => break,
                };
            }
        }
    }

    /// Buffered events of round `id` with `seq >= from_seq`, plus whether
    /// the round is terminal. `None` when the round holds no buffer
    /// (never produced events, or already dropped).
    pub fn progress_since(&self, id: u64, from_seq: u64) -> Option<(Vec<ProgressEvent>, bool)> {
        let book = self.progress.lock().unwrap();
        let r = book.rounds.get(&id)?;
        let events = r.events.iter().filter(|e| e.seq >= from_seq).cloned().collect();
        Some((events, r.done))
    }

    /// Drop round `id`'s buffer eagerly (stream finished or the client
    /// disconnected) instead of waiting for bounded-retention eviction.
    pub fn drop_progress(&self, id: u64) {
        let mut book = self.progress.lock().unwrap();
        book.rounds.remove(&id);
        book.done_order.retain(|&x| x != id);
    }

    /// Rounds currently holding a progress buffer (leak assertions).
    pub fn progress_rounds(&self) -> usize {
        self.progress.lock().unwrap().rounds.len()
    }

    pub fn transfers(&self) -> TransferTotals {
        TransferTotals {
            h2d_ops: self.h2d_ops.load(Ordering::Relaxed),
            d2h_ops: self.d2h_ops.load(Ordering::Relaxed),
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
            kv_h2d_bytes: self.kv_h2d_bytes.load(Ordering::Relaxed),
            kv_dev_hits: self.kv_dev_hits.load(Ordering::Relaxed),
            kv_dev_misses: self.kv_dev_misses.load(Ordering::Relaxed),
            kv_prefetch_overlap_us: self.kv_prefetch_overlap_us.load(Ordering::Relaxed),
            cache_degraded_disk: self.cache_degraded_disk.load(Ordering::Relaxed),
            cache_degraded_device: self.cache_degraded_device.load(Ordering::Relaxed),
            cache_degraded_loader: self.cache_degraded_loader.load(Ordering::Relaxed),
        }
    }
}

/// The `(K, V)` device-buffer pair one cached block's tier entry pins.
type KvPair = (PjRtBuffer, PjRtBuffer);

/// Engine-thread-confined device KV tier.
///
/// SAFETY: `PjRtBuffer` handles are `Rc`-based and not `Sync`, exactly
/// like the ones inside `ModelRuntime`. The tier is moved to the engine
/// thread together with the `Worker` that owns it (it is empty at move
/// time) and is never touched from any other thread afterwards —
/// cross-thread retirement goes through `WorkerShared::request_kv_purge`
/// and is applied by the engine thread itself.
struct EngineKvTier(KvDeviceTier<KvPair>);
unsafe impl Send for EngineKvTier {}

/// The worker engine. Construct, then call [`Worker::start`].
pub struct Worker {
    pub id: usize,
    cfg: EngineConfig,
    rt: ModelRuntime,
    tiers: Arc<TieredStore>,
    loader: CacheLoader,
    lat_model: LatencyModel,
    queue: Arc<WorkerQueue>,
    prepost: Arc<ThreadPool>,
    events: Sender<WorkerEvent>,
    shared: Arc<WorkerShared>,
    stop: Arc<AtomicBool>,
    /// Cluster-wide template table (None for standalone engines, which
    /// keep the seed behaviour: cold-register on first use).
    registry: Option<Arc<TemplateRegistry>>,
    /// Step-scoped scratch arena (reused across steps; see ROADMAP
    /// "Hot path" for the allocation invariant).
    scratch: StepScratch,
    /// Memoized Algorithm-1 plans per (bucket, batch, mode, warm mask).
    plans: PlanCache,
    /// Device-resident KV working set: HBM-budgeted LRU over upload-once
    /// staged-K/V buffers (see `cache::device`). A warm template's
    /// cache-KV blocks run with zero per-step host→device KV transfers.
    kv_tier: EngineKvTier,
    /// The all-cached plan of the `force_all_cached` / `naive_loading`
    /// ablations (built once).
    forced_plan: Option<Arc<PipelinePlan>>,
    /// Deterministic fault injector (None in production: every injection
    /// point compiles down to a null check).
    faults: Option<Arc<FaultInjector>>,
}

impl Worker {
    pub fn new(
        id: usize,
        cfg: EngineConfig,
        rt: ModelRuntime,
        tiers: Arc<TieredStore>,
        lat_model: LatencyModel,
        events: Sender<WorkerEvent>,
    ) -> Worker {
        // FISEdit keeps activations GPU-resident -> free loads.
        let bandwidth = if cfg.system == SystemKind::FisEdit { 0.0 } else { cfg.sim_bandwidth };
        let loader = CacheLoader::spawn(bandwidth);
        // The copy stream is bandwidth-paced by construction, so the DP's
        // load model is exact: seconds = bytes / bandwidth. (The compute
        // model stays calibrated from measurements.)
        let mut lat_model = lat_model;
        lat_model.load = crate::util::stats::LinearFit {
            slope: if bandwidth > 0.0 { 1.0 / bandwidth } else { 0.0 },
            intercept: 0.0,
            r2: 1.0,
        };
        let prepost = Arc::new(ThreadPool::new(
            &format!("prepost-{id}"),
            cfg.prepost_threads.max(1),
        ));
        let queue = WorkerQueue::with_policy(QueuePolicy::from_qos(&cfg.qos));
        let kv_tier = EngineKvTier(KvDeviceTier::new(cfg.kv_device_budget_bytes));
        Worker {
            id,
            cfg,
            rt,
            tiers,
            loader,
            lat_model,
            queue,
            prepost,
            events,
            shared: Arc::new(WorkerShared::default()),
            stop: Arc::new(AtomicBool::new(false)),
            registry: None,
            scratch: StepScratch::default(),
            plans: PlanCache::new(),
            kv_tier,
            forced_plan: None,
            faults: None,
        }
    }

    /// Attach the cluster's template registry: admission then gates on
    /// the cluster-wide lifecycle (park while registering, refuse
    /// retired) instead of cold-registering unknown templates.
    pub fn with_registry(mut self, registry: Arc<TemplateRegistry>) -> Worker {
        self.registry = Some(registry);
        self
    }

    /// Attach a fault injector: the loader thread, the device-KV tier and
    /// the step loop all draw from its isolated RNG streams, so injected
    /// faults decide which rung of the degradation ladder serves a
    /// request — never its outcome. Replaces the loader/KV tier spawned
    /// by `new` (both are empty at this point).
    pub fn with_faults(mut self, faults: Arc<FaultInjector>) -> Worker {
        let bandwidth =
            if self.cfg.system == SystemKind::FisEdit { 0.0 } else { self.cfg.sim_bandwidth };
        self.loader = CacheLoader::spawn_with_faults(bandwidth, Some(Arc::clone(&faults)));
        self.kv_tier = EngineKvTier(
            KvDeviceTier::new(self.cfg.kv_device_budget_bytes).with_faults(Arc::clone(&faults)),
        );
        self.faults = Some(faults);
        self
    }

    /// This worker's cache tier (per-worker in cluster mode).
    pub fn tiers(&self) -> Arc<TieredStore> {
        Arc::clone(&self.tiers)
    }

    /// Submission handle (disaggregation decided by the batching policy).
    pub fn submitter(&self) -> Submitter {
        let pool = matches!(self.cfg.batching, BatchingPolicy::ContinuousDisaggregated)
            .then(|| Arc::clone(&self.prepost));
        let submitter = Submitter::new(
            Arc::clone(&self.queue),
            pool,
            self.rt.config.hidden,
            self.cfg.prepost_cpu_us,
        );
        // Enqueue-time promotion: when this worker's tier holds the
        // template only on disk, start promoting it on the low-priority
        // pre/post lane so the load hides under queuing time (§4.2).
        let tiers = Arc::clone(&self.tiers);
        let pool = Arc::clone(&self.prepost);
        let prefetch: Arc<dyn Fn(&str) + Send + Sync> = Arc::new(move |template_id: &str| {
            if tiers.residency(template_id) == Residency::Disk {
                let tiers = Arc::clone(&tiers);
                let template_id = template_id.to_string();
                pool.submit_low(move || {
                    let _ = tiers.get(&template_id);
                });
            }
        });
        submitter.with_prefetch(prefetch)
    }

    pub fn queue(&self) -> Arc<WorkerQueue> {
        Arc::clone(&self.queue)
    }

    pub fn shared(&self) -> Arc<WorkerShared> {
        Arc::clone(&self.shared)
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Snapshot for the scheduler (running + queued composition, with
    /// the *real* mask ratios of both — the Algo-2 cost model input).
    pub fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot::collect(self.id, &self.queue, &self.shared)
    }

    /// Run the engine loop on the current thread until stopped + drained.
    pub fn run(mut self) -> Result<()> {
        let mut members: Vec<Member> = Vec::new();
        let mut parked: Vec<Parked> = Vec::new();
        let mut preempted: Vec<Member> = Vec::new();
        loop {
            self.reap_defunct();
            self.purge_kv_tier();
            self.admit(&mut members, &mut parked, &mut preempted)?;
            if members.is_empty() {
                if self.stop.load(Ordering::Relaxed)
                    && self.queue.pending() == 0
                    && preempted.is_empty()
                {
                    // parked requests will never see their registration
                    // from a stopping cluster; resolve their tickets
                    for p in parked.drain(..) {
                        self.resolve_unrun(p.prep.request.id, EditError::WorkerShutdown);
                    }
                    break;
                }
                self.queue.wait_for_work(Duration::from_millis(1));
                continue;
            }
            // Simulated worker crash at a step boundary: the "restarted"
            // engine re-runs every in-flight member from x_T. Requests
            // are never lost, and because denoising is deterministic the
            // replay converges to the no-fault latents bit-for-bit.
            if self.faults.as_ref().is_some_and(|f| f.should(FaultSite::WorkerCrash)) {
                self.crash_restart(&mut members);
            }
            self.run_step(&mut members)?;
            self.checkpoint_members(&members);
            self.complete_finished(&mut members);
            self.publish(&members);
        }
        Ok(())
    }

    /// Directory for step-boundary latent checkpoints — a subtree of the
    /// cache spill dir, so checkpoints ride the same disk budget story.
    fn checkpoint_dir(&self) -> PathBuf {
        self.cfg.spill_dir.join("checkpoints")
    }

    /// Spill a latent checkpoint for every member whose step count just
    /// crossed a `checkpoint_every_steps` boundary. TeaCache members are
    /// skipped: their replayed-eps gate state is not checkpointed, so a
    /// resume would not be bit-identical — they restart from step 0.
    /// Write errors are logged and ignored (a checkpoint is an
    /// optimization; losing one only costs recompute).
    fn checkpoint_members(&self, members: &[Member]) {
        let every = self.cfg.checkpoint_every_steps;
        if every == 0 {
            return;
        }
        let total = self.rt.config.steps;
        let dir = self.checkpoint_dir();
        for m in members {
            if m.gate.is_some() || m.step == 0 || m.step >= total || m.step % every != 0 {
                continue;
            }
            let req = &m.prep.request;
            let sum =
                request_checksum(req.id, req.prompt_seed, m.prep.masked_count, &req.template_id);
            if let Err(e) = save_checkpoint(&dir, req.id, m.step, sum, m.latent.data()) {
                eprintln!("worker {}: checkpoint for request {} failed: {e}", self.id, req.id);
            }
        }
    }

    /// Reset every in-flight member to its initial state, exactly as a
    /// restarted worker that lost its step-loop progress would observe.
    /// Only latency (and the interruption counter) shows the crash.
    ///
    /// With checkpointing enabled, a member whose last step-boundary
    /// checkpoint validates (request checksum + payload checksum + shape)
    /// resumes from that step instead of x_T — the denoise loop is
    /// deterministic, so the resumed trajectory is bit-identical to an
    /// uninterrupted run.
    fn crash_restart(&self, members: &mut [Member]) {
        let dir = self.checkpoint_dir();
        for m in members.iter_mut() {
            m.interruptions += 1;
            m.last_eps = None;
            if self.cfg.checkpoint_every_steps > 0 && m.gate.is_none() {
                let req = &m.prep.request;
                let sum = request_checksum(
                    req.id,
                    req.prompt_seed,
                    m.prep.masked_count,
                    &req.template_id,
                );
                if let Some((step, data)) =
                    load_checkpoint(&dir, req.id, sum, m.latent.data().len())
                {
                    m.latent.data_mut().copy_from_slice(&data);
                    m.step = step;
                    continue;
                }
            }
            m.latent = m.acts.initial_latent();
            m.step = 0;
            if m.gate.is_some() {
                m.gate = Some(TeaCacheGate::new(self.cfg.teacache_threshold));
            }
        }
    }

    /// Apply cross-thread retirement to the device KV tier: drop every
    /// purge-requested template's entries (the engine thread is between
    /// steps here, so nothing is pinned by a running batch).
    fn purge_kv_tier(&mut self) {
        for t in self.shared.drain_kv_purges() {
            self.kv_tier.0.purge_template(&t);
        }
    }

    /// Sweep the queue for cancel-marked or deadline-expired entries and
    /// resolve their tickets without spending denoise steps.
    fn reap_defunct(&self) {
        for (id, err) in self.queue.drain_defunct(Instant::now()) {
            self.resolve_unrun(id, err);
        }
    }

    /// Resolve a request this worker holds (parked, preempted, or just
    /// popped) without running it: clear its held flag and report the
    /// terminal error to the collector.
    fn resolve_unrun(&self, id: u64, err: EditError) {
        self.queue.set_held(id, false);
        let _ = self.events.send(WorkerEvent::Finished {
            id,
            worker: self.id,
            result: Err(err),
        });
    }

    /// Spawn the engine loop on its own thread.
    pub fn start(self) -> std::thread::JoinHandle<Result<()>> {
        std::thread::Builder::new()
            .name(format!("worker-{}", self.id))
            .spawn(move || self.run())
            .expect("spawn worker")
    }

    // -- admission -----------------------------------------------------------

    fn admit(
        &mut self,
        members: &mut Vec<Member>,
        parked: &mut Vec<Parked>,
        preempted: &mut Vec<Member>,
    ) -> Result<()> {
        let cap = self.cfg.max_batch.min(self.rt.max_batch_bucket());
        // whether the batch was drained *before* parked admissions, so a
        // resumed parked request doesn't make static batching skip the
        // queue-fill below and run an underfilled batch
        let drained_batch = members.is_empty();
        self.service_parked(members, parked, cap);
        self.service_preempted(members, preempted, cap);
        match self.cfg.batching {
            BatchingPolicy::Static => {
                // join only when the running batch has fully drained
                if !drained_batch {
                    return Ok(());
                }
                while members.len() < cap {
                    // don't pop requests we could only park when the
                    // parked set is full — they stay queued (visible in
                    // queue depths, still cancellable)
                    let park_room = parked.len() < cap;
                    let admit = |tpl: &str, _k: usize| {
                        park_room
                            || !matches!(self.template_gate(tpl), TemplateGate::Pending)
                    };
                    let Some(prep) = self.take_prepared_if(members, &admit) else { break };
                    self.gate_or_admit(prep, members, parked);
                }
            }
            BatchingPolicy::ContinuousInline | BatchingPolicy::ContinuousDisaggregated => {
                // QoS: when the batch is full and an Interactive request
                // waits, park the lowest-class member at this step
                // boundary so the fill loop below can admit the
                // interactive one (the step-level analogue of the
                // paper's one-step join).
                self.preempt_for_interactive(members, preempted, cap);
                // Step-level join (the paper's continuous batching, §4.3),
                // bucket-aware: a joining request must not inflate the
                // running batch's token bucket unless the batch is nearly
                // empty (<= 1 member). Ordered on the best queue
                // candidate only (priority order under QoS, FIFO
                // otherwise), so deferred large-mask requests cannot
                // starve. This is the shape-bucketed analogue of the
                // paper's heterogeneous-mask batching (their kernels
                // handle per-member token counts; XLA programs are
                // shape-static).
                loop {
                    if members.len() >= cap {
                        break;
                    }
                    // a preempted member whose bucket no longer fits the
                    // running batch blocks new admissions (the same
                    // no-skip rule the queue front gets): the batch
                    // drains, the member rejoins, then filling resumes
                    if preempted
                        .iter()
                        .any(|m| !self.bucket_fits(members, m.prep.masked_count))
                    {
                        break;
                    }
                    let batch_bucket = members
                        .iter()
                        .map(|m| m.cached_bucket)
                        .max()
                        .unwrap_or(usize::MAX);
                    let admit_any = members.len() <= 1;
                    let park_room = parked.len() < cap;
                    let admit = |tpl: &str, k: usize| {
                        let fits = admit_any
                            || !self.mask_aware()
                            || self.rt.config.bucket_for(k) <= batch_bucket;
                        // registering-template requests are only popped
                        // while the (cap-bounded) parked set has room
                        fits
                            && (park_room
                                || !matches!(self.template_gate(tpl), TemplateGate::Pending))
                    };
                    let Some(prep) = self.take_prepared_if(members, &admit) else { break };
                    self.gate_or_admit(prep, members, parked);
                }
            }
        }
        Ok(())
    }

    /// Whether a request with `masked_count` tokens may join the running
    /// batch without inflating its token bucket (the same rule the admit
    /// loop applies to queued requests).
    fn bucket_fits(&self, members: &[Member], masked_count: usize) -> bool {
        if members.len() <= 1 || !self.mask_aware() {
            return true;
        }
        let batch_bucket = members
            .iter()
            .map(|m| m.cached_bucket)
            .max()
            .unwrap_or(usize::MAX);
        self.rt.config.bucket_for(masked_count) <= batch_bucket
    }

    /// Re-check parked requests: resolve cancel marks first, then admit
    /// the ones whose template became ready (bucket rules permitting),
    /// refuse the ones whose template retired or failed, and time out the
    /// ones that waited past their deadline (only while still pending — a
    /// ready request that merely awaits a compatible batch bucket is
    /// never timed out here).
    fn service_parked(&self, members: &mut Vec<Member>, parked: &mut Vec<Parked>, cap: usize) {
        let join_ok = match self.cfg.batching {
            // static batching only joins a drained batch
            BatchingPolicy::Static => members.is_empty(),
            _ => true,
        };
        let mut i = 0;
        while i < parked.len() {
            let id = parked[i].prep.request.id;
            if self.queue.take_cancel(id) {
                let _ = parked.swap_remove(i);
                self.resolve_unrun(id, EditError::Cancelled);
                continue;
            }
            // a deadline that lapsed while parked counts as expired-in-
            // queue: drop it before it can burn denoise steps
            let expired = self.cfg.qos.enabled
                && matches!(parked[i].prep.request.deadline, Some(d) if Instant::now() >= d);
            if expired {
                let _ = parked.swap_remove(i);
                self.resolve_unrun(id, EditError::DeadlineExceeded);
                continue;
            }
            match self.template_gate(&parked[i].prep.request.template_id) {
                TemplateGate::Ready
                    if join_ok
                        && members.len() < cap
                        && self.bucket_fits(members, parked[i].prep.masked_count) =>
                {
                    let p = parked.swap_remove(i);
                    // atomic un-park: a cancel that raced in wins
                    if self.queue.release_held(id) {
                        self.admit_member(p.prep, members);
                    } else {
                        self.resolve_unrun(id, EditError::Cancelled);
                    }
                }
                TemplateGate::Refused(err) => {
                    let _ = parked.swap_remove(i);
                    self.resolve_unrun(id, err);
                }
                TemplateGate::Pending if Instant::now() >= parked[i].deadline => {
                    let _ = parked.swap_remove(i);
                    self.resolve_unrun(id, EditError::Timeout);
                }
                _ => i += 1,
            }
        }
    }

    /// Re-admit preempted members: cancel marks resolve first (the
    /// satellite fix — `DELETE` reaches preempted members, which release
    /// their slot promptly), then each member rejoins as soon as a slot
    /// is free and its bucket fits. No `Started` event — the request
    /// never left the `Running` state; its latent resumes exactly where
    /// it parked.
    fn service_preempted(
        &self,
        members: &mut Vec<Member>,
        preempted: &mut Vec<Member>,
        cap: usize,
    ) {
        let join_ok = match self.cfg.batching {
            BatchingPolicy::Static => members.is_empty(),
            _ => true,
        };
        let mut i = 0;
        while i < preempted.len() {
            let id = preempted[i].prep.request.id;
            if self.queue.take_cancel(id) {
                let _ = preempted.swap_remove(i);
                self.resolve_unrun(id, EditError::Cancelled);
                continue;
            }
            if join_ok
                && members.len() < cap
                && self.bucket_fits(members, preempted[i].prep.masked_count)
            {
                let m = preempted.swap_remove(i);
                // atomic resume: a cancel that raced in wins instead of
                // silently re-running a request the client cancelled
                if self.queue.release_held(id) {
                    members.push(m);
                } else {
                    self.resolve_unrun(id, EditError::Cancelled);
                }
                continue;
            }
            i += 1;
        }
    }

    /// QoS preemption (tentpole part 2): with the batch full and an
    /// `Interactive` request waiting, park the lowest-class member at
    /// this step boundary — its latent and step counter move to the
    /// preempted set and rejoin later, bit-identical to an uninterrupted
    /// run. Each member is preempted at most once, and at most one member
    /// per engine iteration, so preemption cannot thrash.
    fn preempt_for_interactive(
        &self,
        members: &mut Vec<Member>,
        preempted: &mut Vec<Member>,
        cap: usize,
    ) {
        if !self.cfg.qos.enabled || members.len() < cap {
            return;
        }
        // the *next pop* must be a genuinely Interactive request — if an
        // aged-up lower class outranks it, that one gets the next natural
        // slot and evicting a member for it would invert the intent
        let peek = match self.cfg.batching {
            BatchingPolicy::ContinuousDisaggregated => self.queue.peek_best_ready(),
            _ => self.queue.peek_best_raw(),
        };
        let Some((rank, masked)) = peek else { return };
        if rank != Priority::Interactive.rank() {
            return;
        }
        let victim = members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.rank() > Priority::Interactive.rank() && m.preemptions == 0)
            // lowest class first; among those, the least-progressed
            // member (most remaining steps), so a nearly-done member is
            // not held up at the finish line
            .max_by_key(|(_, m)| (m.rank(), std::cmp::Reverse(m.step)))
            .map(|(i, _)| i);
        let Some(i) = victim else { return };
        // only evict when (a) the interactive request could actually take
        // the freed slot under the bucket rule — otherwise the slot would
        // sit idle for the rest of the batch's lifetime — and (b) the
        // victim's own bucket still fits the remaining batch, so it is
        // never parked behind a batch it can no longer rejoin
        let remaining = members.len() - 1;
        let fits = if remaining <= 1 || !self.mask_aware() {
            true
        } else {
            let batch_bucket = members
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, m)| m.cached_bucket)
                .max()
                .unwrap_or(usize::MAX);
            self.rt.config.bucket_for(masked) <= batch_bucket
                && members[i].cached_bucket <= batch_bucket
        };
        if !fits {
            return;
        }
        let mut m = members.swap_remove(i);
        m.preemptions += 1;
        m.interruptions += 1;
        self.queue.set_held(m.prep.request.id, true);
        preempted.push(m);
    }

    /// Where a popped request's template stands right now.
    fn template_gate(&self, template_id: &str) -> TemplateGate {
        if self.tiers.is_host_resident(template_id) {
            return TemplateGate::Ready;
        }
        let Some(registry) = &self.registry else {
            return TemplateGate::Ready; // standalone: cold-register path
        };
        match registry.state(template_id) {
            // ready (tier promotes/cold-fills in make_member) or direct
            // submission the registry adopted without a trace
            Some(TemplateState::Ready) | None => TemplateGate::Ready,
            Some(TemplateState::Registering) => TemplateGate::Pending,
            Some(TemplateState::Retired) => {
                TemplateGate::Refused(EditError::TemplateRetired(template_id.to_string()))
            }
            Some(TemplateState::Failed(reason)) => TemplateGate::Refused(EditError::Internal(
                format!("template {template_id:?} failed registration: {reason}"),
            )),
        }
    }

    /// Admit a popped request, park it, or refuse it, per its template's
    /// lifecycle state. Cancel marks and expired deadlines resolve here
    /// too — the last check before a request joins the batch.
    fn gate_or_admit(
        &self,
        prep: PreparedRequest,
        members: &mut Vec<Member>,
        parked: &mut Vec<Parked>,
    ) {
        let id = prep.request.id;
        if self.queue.take_cancel(id) {
            self.resolve_unrun(id, EditError::Cancelled);
            return;
        }
        let expired = matches!(prep.request.deadline, Some(d) if Instant::now() >= d);
        if self.cfg.qos.enabled && expired {
            self.resolve_unrun(id, EditError::DeadlineExceeded);
            return;
        }
        match self.template_gate(&prep.request.template_id) {
            TemplateGate::Ready => self.admit_member(prep, members),
            TemplateGate::Pending => {
                self.queue.set_held(id, true);
                parked.push(Parked {
                    deadline: Instant::now()
                        + Duration::from_millis(self.cfg.registration_wait_ms),
                    prep,
                });
            }
            TemplateGate::Refused(err) => self.resolve_unrun(id, err),
        }
    }

    /// Turn a prepared request into a batch member, reporting the
    /// queued -> running transition to the collector. Registration
    /// failures become per-request errors instead of killing the engine.
    fn admit_member(&self, prep: PreparedRequest, members: &mut Vec<Member>) {
        let id = prep.request.id;
        let template = prep.request.template_id.clone();
        match self.make_member(prep) {
            Ok(m) => {
                let _ = self.events.send(WorkerEvent::Started { id, worker: self.id });
                members.push(m);
            }
            Err(e) => {
                // typed lifecycle refusals pass through; other
                // registration/cache faults are server errors (template
                // existence was the frontend's check, not ours)
                let result = match e.downcast::<EditError>() {
                    Ok(typed) => Err(typed),
                    Err(e) => Err(EditError::Internal(format!(
                        "admitting {template:?}: {e:#}"
                    ))),
                };
                let _ = self.events.send(WorkerEvent::Finished {
                    id,
                    worker: self.id,
                    result,
                });
            }
        }
    }

    /// Pull one prepared request if the queue front satisfies `admit`
    /// (called with its template id + masked-token count), preprocessing
    /// inline when the policy demands it (counting interruptions for
    /// current members — the §6.4 microbenchmark's metric).
    fn take_prepared_if(
        &self,
        members: &mut [Member],
        admit: &dyn Fn(&str, usize) -> bool,
    ) -> Option<PreparedRequest> {
        match self.cfg.batching {
            BatchingPolicy::ContinuousDisaggregated => self
                .queue
                .pop_ready_if(|p| admit(&p.request.template_id, p.masked_count)),
            _ => {
                let req = self
                    .queue
                    .pop_raw_if(|r| admit(&r.template_id, r.mask.masked_count()))?;
                if !members.is_empty() {
                    for m in members.iter_mut() {
                        m.interruptions += 1;
                    }
                }
                Some(preprocess(req, self.rt.config.hidden, self.cfg.prepost_cpu_us))
            }
        }
    }

    fn make_member(&self, prep: PreparedRequest) -> Result<Member> {
        let acts = self.ensure_registered(&prep.request.template_id)?;
        let latent = acts.initial_latent();
        let cfg = &self.rt.config;
        let bucket = cfg.bucket_for(prep.masked_count);
        let cached_ids = Arc::new(prep.perm.cached_ids(bucket).to_vec());
        let gate = (self.cfg.system == SystemKind::TeaCache)
            .then(|| TeaCacheGate::new(self.cfg.teacache_threshold));
        Ok(Member {
            prep,
            acts,
            latent,
            step: 0,
            joined: Instant::now(),
            interruptions: 0,
            steps_computed: 0,
            cached_ids,
            cached_bucket: bucket,
            last_eps: None,
            gate,
            preemptions: 0,
        })
    }

    /// Fetch (and on cold miss, register) a template's activations. In
    /// cluster mode a registration that is already in flight elsewhere is
    /// awaited instead of duplicated on the engine thread.
    ///
    /// A disk-tier promotion failure (I/O error, corrupt spill, open
    /// breaker) is *not* a request failure: it demotes to the bottom rung
    /// of the degradation ladder — full-model recompute via the cold
    /// registration path below, which is deterministic and therefore
    /// bit-identical to a cache hit.
    pub fn ensure_registered(&self, template_id: &str) -> Result<Arc<TemplateActivations>> {
        match self.tiers.get(template_id) {
            Ok(Some(acts)) => return Ok(acts),
            Ok(None) => {}
            Err(_) => self.rt.note_cache_degraded_disk(),
        }
        if let Some(registry) = &self.registry {
            match registry.state(template_id) {
                Some(TemplateState::Registering) => {
                    registry
                        .wait_ready(
                            template_id,
                            Duration::from_millis(self.cfg.registration_wait_ms),
                        )
                        .map_err(anyhow::Error::new)?;
                    match self.tiers.get(template_id) {
                        Ok(Some(acts)) => return Ok(acts),
                        Ok(None) => {}
                        Err(_) => self.rt.note_cache_degraded_disk(),
                    }
                }
                // never resurrect a retired template's bytes via the
                // cold-register fallback (admission raced a purge)
                Some(TemplateState::Retired) => {
                    return Err(anyhow::Error::new(EditError::TemplateRetired(
                        template_id.to_string(),
                    )))
                }
                _ => {}
            }
        }
        let (acts, _) = register_template(&self.rt, template_id, self.cfg.cache_mode)
            .context("template registration")?;
        self.tiers.insert(Arc::clone(&acts))?;
        Ok(acts)
    }

    // -- step execution -------------------------------------------------------

    fn mask_aware(&self) -> bool {
        matches!(self.cfg.system, SystemKind::InstGenIE | SystemKind::FisEdit)
    }

    fn run_step(&mut self, members: &mut [Member]) -> Result<()> {
        if self.mask_aware() {
            let n = members
                .iter()
                .map(|m| m.cached_bucket)
                .max()
                .unwrap_or(self.rt.config.tokens);
            if n >= self.rt.config.tokens {
                self.step_full(members)
            } else {
                self.step_masked(members, n)
            }
        } else {
            self.step_full(members)
        }
        .map(|_| self.shared.steps_executed.fetch_add(1, Ordering::Relaxed))
        .map(|_| ())
    }

    /// Build a member's denoiser input h = x + temb(t) (+ conditioning on
    /// the genuinely masked rows) into a reused scratch buffer.
    fn build_hidden_into(rt: &ModelRuntime, m: &Member, out: &mut Vec<f32>, grows: &mut usize) {
        let h = rt.config.hidden;
        if out.capacity() < m.latent.data().len() {
            *grows += 1;
        }
        out.clear();
        out.extend_from_slice(m.latent.data());
        let temb = rt.weights().temb_row(m.step);
        for (i, v) in out.iter_mut().enumerate() {
            *v += temb[i % h];
        }
        for &id in m.prep.perm.compute_ids(m.prep.masked_count) {
            let row = &mut out[id * h..(id + 1) * h];
            for (v, c) in row.iter_mut().zip(&m.prep.conditioning) {
                *v += c;
            }
        }
    }

    /// Build every member's denoiser input into the scratch hidden
    /// buffers (one reused full (L, H) buffer per member).
    fn ensure_hidden(&mut self, members: &[Member]) {
        if self.scratch.hidden.len() < members.len() {
            self.scratch.grows += 1;
            self.scratch.hidden.resize_with(members.len(), Vec::new);
        }
        for (i, m) in members.iter().enumerate() {
            // split borrow: hidden[i] and grows are disjoint scratch fields
            let StepScratch { hidden, grows, .. } = &mut self.scratch;
            Self::build_hidden_into(&self.rt, m, &mut hidden[i], grows);
        }
    }

    /// Advance one member's latent from a full (L, H) eps view: masked
    /// rows follow the computed eps, unmasked rows are pinned to the
    /// template trajectory (standard diffusion inpainting: regenerate
    /// only the mask). The shared tail of `step_full` and `step_masked`;
    /// eps rows are gathered in place — no staging buffers, no id clones.
    #[allow(clippy::too_many_arguments)]
    fn advance_latent(
        sched: &Schedule,
        h: usize,
        prep: &PreparedRequest,
        acts: &TemplateActivations,
        step: &mut usize,
        latent: &mut Latent,
        eps_src: &[f32],
    ) {
        let masked = prep.perm.compute_ids(prep.masked_count);
        sched.update_rows_gathered(*step, latent.data_mut(), h, masked, eps_src);
        let unmasked = prep.perm.cached_ids(prep.masked_count);
        sched.update_rows_gathered(*step, latent.data_mut(), h, unmasked, acts.eps(*step));
        *step += 1;
    }

    /// Run blocks `[first, end)` as one full-sequence device-resident
    /// chain over the pre-packed `scratch.full` input, leaving the final
    /// output in `scratch.out`. `device: false` is the host-round-trip
    /// reference (one upload + one download per block).
    fn run_full_chain(
        rt: &ModelRuntime,
        scratch: &mut StepScratch,
        first: usize,
        end: usize,
        bb: usize,
        device: bool,
    ) -> Result<()> {
        let (l, h) = (rt.config.tokens, rt.config.hidden);
        let len = bb * l * h;
        if device {
            let mut x_buf = rt.upload_activations(&scratch.full[..len], &[bb, l, h])?;
            for blk in first..end {
                x_buf = rt.run_block_y_dev(blk, l, bb, &x_buf)?;
            }
            rt.fetch_block_output(ArtifactKind::BlockY, l, bb, &x_buf, &mut scratch.out)?;
        } else {
            let mut cur = scratch.full[..len].to_vec();
            for blk in first..end {
                cur = rt.run_block_y(blk, l, bb, &cur)?;
            }
            scratch.out = cur;
        }
        Ok(())
    }

    /// Full-sequence step (Diffusers / TeaCache / mask saturating bucket).
    fn step_full(&mut self, members: &mut [Member]) -> Result<()> {
        let cfg = self.rt.config.clone();
        let (l, h) = (cfg.tokens, cfg.hidden);
        let b = members.len();
        let bb = self.rt.batch_bucket_for(b);

        // TeaCache: gate each member; if everyone skips, replay without
        // touching the device.
        self.scratch.compute.clear();
        self.scratch.compute.resize(b, true);
        if self.cfg.system == SystemKind::TeaCache {
            for (i, m) in members.iter_mut().enumerate() {
                let temb = self.rt.weights().temb_row(m.step);
                let gate = m.gate.as_mut().expect("teacache gate");
                self.scratch.compute[i] = !(gate.should_skip(temb) && m.last_eps.is_some());
            }
        }

        let any_compute = self.scratch.compute.iter().any(|&c| c);
        if any_compute {
            // build each member's hidden, then pack (bb, L, H) with
            // last-member padding
            self.ensure_hidden(members);
            self.scratch.pack_full_rows(b, l, h, bb);
            let device = self.cfg.device_resident
                && self.rt.device_chain_supported(ArtifactKind::BlockY, l, bb);
            Self::run_full_chain(&self.rt, &mut self.scratch, 0, cfg.blocks, bb, device)?;
        }

        // per-member latent update
        for (i, m) in members.iter_mut().enumerate() {
            let Member { prep, acts, latent, step, last_eps, steps_computed, gate, .. } = m;
            let eps_src: &[f32] = if self.scratch.compute[i] {
                *steps_computed += 1;
                let row = &self.scratch.out[i * l * h..(i + 1) * l * h];
                if gate.is_some() {
                    // TeaCache keeps the eps for replay (reusing the
                    // member's buffer — no per-step allocation)
                    match last_eps {
                        Some(buf) => buf.copy_from_slice(row),
                        None => *last_eps = Some(row.to_vec()),
                    }
                    last_eps.as_deref().expect("just stored")
                } else {
                    row
                }
            } else {
                last_eps.as_deref().expect("replayed eps")
            };
            Self::advance_latent(self.rt.schedule(), h, prep, acts, step, latent, eps_src);
        }
        Ok(())
    }

    /// Mask-aware step at token bucket `n` with the Algo-1 pipeline.
    ///
    /// Device-resident hot path: activations are uploaded once per
    /// contiguous same-mode block run and downloaded once at the run's
    /// end — between consecutive cached blocks, `scatter(compute_ids,
    /// out)` followed by `gather(compute_ids)` is the identity, so block
    /// i+1's packed input *is* block i's output buffer. The full-hidden
    /// scatter (computed rows + staged-Y replenish, Fig. 5) happens only
    /// at cached->full transitions and for the step-end latent update.
    fn step_masked(&mut self, members: &mut [Member], n: usize) -> Result<()> {
        let cfg = self.rt.config.clone();
        let (l, h) = (cfg.tokens, cfg.hidden);
        let b = members.len();
        let bb = self.rt.batch_bucket_for(b);
        let mode = self.cfg.cache_mode;
        let kind = match mode {
            CacheMode::CacheY => ArtifactKind::BlockY,
            CacheMode::CacheKV => ArtifactKind::BlockKV,
        };
        let device = self.cfg.device_resident
            && self.rt.device_chain_supported(kind, n, bb)
            && self.rt.device_chain_supported(ArtifactKind::BlockY, l, bb);

        // cached-row id sets at this bucket (may exceed a member's own
        // bucket; the permutation prefix property makes this safe)
        let cached_ids: Vec<Arc<Vec<usize>>> = members
            .iter()
            .map(|m| {
                if m.cached_bucket == n {
                    Arc::clone(&m.cached_ids)
                } else {
                    Arc::new(m.prep.perm.cached_ids(n).to_vec())
                }
            })
            .collect();

        // -- device KV tier: residency probe ----------------------------------
        // Solo batches only: the packed K/V layout interleaves members,
        // so a multi-member buffer is batch-composition-specific and
        // never reusable across steps.
        let kv_tier_usable = device
            && mode == CacheMode::CacheKV
            && b == 1
            && self.kv_tier.0.budget() > 0;
        let kv_keys: Option<Vec<KvKey>> = if kv_tier_usable {
            let tier = &mut self.kv_tier.0;
            let template = tier.intern_template(&members[0].prep.request.template_id);
            let ids = tier.intern_ids(&cached_ids[0]);
            let step = members[0].step as u32;
            Some(
                (0..cfg.blocks)
                    .map(|blk| KvKey {
                        template,
                        ids,
                        step,
                        block: blk as u32,
                        bucket: bb as u32,
                    })
                    .collect(),
            )
        } else {
            None
        };
        // Per-block warmth (bit i = block i is device-resident): feeds the
        // DP (a warm block's upload cost collapses to 0) and the loader
        // (`skip_kv`). Blocks past 64 conservatively count as cold.
        let warm_mask: u64 = kv_keys.as_ref().map_or(0, |keys| {
            keys.iter()
                .take(64)
                .enumerate()
                .filter(|(_, key)| self.kv_tier.0.contains(key))
                .fold(0, |m, (i, _)| m | (1u64 << i))
        });
        let is_warm = |blk: usize| blk < 64 && (warm_mask >> blk) & 1 == 1;
        // Pin warm entries for the whole step: once a block's load is
        // submitted with `skip_kv`, a later cold block's insert must not
        // evict the entry that promised to serve it. Unpinned after the
        // latent update (an engine error aborts the worker, so pins
        // cannot leak into a later step).
        let mut step_pins: Vec<KvKey> = Vec::new();
        if let Some(keys) = &kv_keys {
            for (i, key) in keys.iter().enumerate().take(64) {
                if is_warm(i) {
                    self.kv_tier.0.pin(key);
                    step_pins.push(*key);
                }
            }
        }

        // -- plan (Algo 1, memoized per (n, b, mode, warm mask)) --------------
        let plan: Arc<PipelinePlan> = if self.cfg.force_all_cached || self.cfg.naive_loading {
            if self.forced_plan.as_ref().map(|p| p.use_cache.len()) != Some(cfg.blocks) {
                self.forced_plan = Some(Arc::new(PipelinePlan {
                    use_cache: vec![true; cfg.blocks],
                    latency: 0.0,
                }));
            }
            Arc::clone(self.forced_plan.as_ref().expect("just built"))
        } else {
            let lat = &self.lat_model;
            let mode_tag = match mode {
                CacheMode::CacheY => 0u8,
                CacheMode::CacheKV => 1u8,
            };
            self.plans.plan_for(n, b, mode_tag, warm_mask, || {
                lat.step_costs_with(&cfg, n, b, mode, warm_mask)
            })
        };

        // -- submit loads (pipeline order) ------------------------------------
        let mut staged_rx: Vec<Option<Receiver<StagedBlock>>> = (0..cfg.blocks).map(|_| None).collect();
        let mut staged_now: Vec<Option<StagedBlock>> = (0..cfg.blocks).map(|_| None).collect();
        let gathers = |step_of: &dyn Fn(usize) -> usize| -> Vec<MemberGather> {
            members
                .iter()
                .enumerate()
                .map(|(i, m)| MemberGather {
                    store: Arc::clone(&m.acts),
                    step: step_of(i),
                    ids: Arc::clone(&cached_ids[i]),
                })
                .collect()
        };
        let steps: Vec<usize> = members.iter().map(|m| m.step).collect();
        if self.cfg.naive_loading {
            // Fig. 9-Top: the compute stream performs all loads up front.
            for blk in 0..cfg.blocks {
                if plan.use_cache[blk] {
                    let g = gathers(&|i| steps[i]);
                    staged_now[blk] = Some(self.loader.gather_sync(blk, g, mode, bb));
                }
            }
        } else {
            for blk in 0..cfg.blocks {
                if plan.use_cache[blk] {
                    let g = gathers(&|i| steps[i]);
                    // device-resident K/V: gather (and pace) only the Y rows
                    let skip_kv = kv_keys.is_some() && is_warm(blk);
                    staged_rx[blk] = Some(self.loader.submit(blk, g, mode, bb, skip_kv));
                }
            }
        }

        // -- hidden state: one full (L, H) buffer per member (reused) ---------
        self.ensure_hidden(members);

        // -- block runs: contiguous same-mode chains --------------------------
        let mut blk = 0;
        while blk < cfg.blocks {
            let cached = plan.use_cache[blk];
            let mut end = blk + 1;
            while end < cfg.blocks && plan.use_cache[end] == cached {
                end += 1;
            }
            if cached {
                if device {
                    // pack compute rows once for the whole run
                    self.scratch.pack_compute_rows(members, n, h, bb);
                    let mut x_buf = self
                        .rt
                        .upload_activations(&self.scratch.packed[..bb * n * h], &[bb, n, h])?;
                    let mut last_y: Option<Vec<Vec<f32>>> = None;
                    // block k+1's K/V, acquired by the second copy stream
                    // while block k computes (tier hit: pinned resident
                    // buffer; miss: uploaded here, hidden under compute)
                    let mut prefetched: Option<(usize, Rc<KvPair>)> = None;
                    for k in blk..end {
                        let mut staged = match take_staged(&mut staged_now, &mut staged_rx, k) {
                            Some(s) => s,
                            None => self.staged_fallback(k, gathers(&|i| steps[i]), mode, bb),
                        };
                        x_buf = match mode {
                            CacheMode::CacheY => self.rt.run_block_y_dev(k, n, bb, &x_buf)?,
                            CacheMode::CacheKV => {
                                let kv = match prefetched.take() {
                                    Some((pk, kv)) if pk == k => kv,
                                    _ => Self::acquire_kv(
                                        &self.rt,
                                        &mut self.kv_tier.0,
                                        &kv_keys,
                                        k,
                                        &mut staged,
                                        &[bb, l - n, h],
                                        &mut step_pins,
                                    )?,
                                };
                                // second copy stream: resolve block k+1's
                                // K/V now so its upload (if any) overlaps
                                // this block's compute
                                if k + 1 < end {
                                    if let Some(mut s) =
                                        try_staged(&mut staged_now, &mut staged_rx, k + 1)
                                    {
                                        let t0 = Instant::now();
                                        let next = Self::acquire_kv(
                                            &self.rt,
                                            &mut self.kv_tier.0,
                                            &kv_keys,
                                            k + 1,
                                            &mut s,
                                            &[bb, l - n, h],
                                            &mut step_pins,
                                        )?;
                                        self.rt.note_kv_prefetch_overlap(t0.elapsed());
                                        prefetched = Some((k + 1, next));
                                        staged_now[k + 1] = Some(s);
                                    }
                                }
                                self.rt.run_block_kv_dev(k, n, bb, &x_buf, &kv.0, &kv.1)?
                            }
                        };
                        last_y = Some(staged.y);
                    }
                    self.rt
                        .fetch_block_output(kind, n, bb, &x_buf, &mut self.scratch.out)?;
                    // scatter computed rows back (the latent update and any
                    // following full run read them from the hidden buffer)
                    for i in 0..b {
                        let ids = members[i].prep.perm.compute_ids(n);
                        scatter_rows(
                            &mut self.scratch.hidden[i],
                            h,
                            ids,
                            &self.scratch.out[i * n * h..(i + 1) * n * h],
                        );
                    }
                    // replenish cached rows (Fig. 5) only at a cached->full
                    // transition: nothing else reads them this step
                    if end < cfg.blocks {
                        let y = last_y.expect("cached run is non-empty");
                        for i in 0..b {
                            scatter_rows(&mut self.scratch.hidden[i], h, &cached_ids[i], &y[i]);
                        }
                    }
                } else {
                    // host-round-trip reference: per-block upload/download
                    // with the full scatter/gather of the seed loop
                    for k in blk..end {
                        let staged = match take_staged(&mut staged_now, &mut staged_rx, k) {
                            Some(s) => s,
                            None => self.staged_fallback(k, gathers(&|i| steps[i]), mode, bb),
                        };
                        self.scratch.pack_compute_rows(members, n, h, bb);
                        let out = match mode {
                            CacheMode::CacheY => {
                                self.rt.run_block_y(k, n, bb, &self.scratch.packed[..bb * n * h])?
                            }
                            CacheMode::CacheKV => {
                                let (kc, vc) = staged.kv_packed.as_ref().expect("kv staged");
                                self.rt.run_block_kv(
                                    k,
                                    n,
                                    bb,
                                    &self.scratch.packed[..bb * n * h],
                                    kc,
                                    vc,
                                )?
                            }
                        };
                        // scatter computed rows + replenish cached rows
                        for (i, m) in members.iter().enumerate() {
                            let ids = m.prep.perm.compute_ids(n);
                            let src = &out[i * n * h..(i + 1) * n * h];
                            scatter_rows(&mut self.scratch.hidden[i], h, ids, src);
                            scatter_rows(
                                &mut self.scratch.hidden[i],
                                h,
                                &cached_ids[i],
                                &staged.y[i],
                            );
                        }
                    }
                }
            } else {
                // full run: all L tokens, no loads
                if device {
                    self.scratch.pack_full_rows(b, l, h, bb);
                    Self::run_full_chain(&self.rt, &mut self.scratch, blk, end, bb, true)?;
                    for i in 0..b {
                        let StepScratch { hidden, out, .. } = &mut self.scratch;
                        hidden[i].copy_from_slice(&out[i * l * h..(i + 1) * l * h]);
                    }
                } else {
                    for k in blk..end {
                        self.scratch.pack_full_rows(b, l, h, bb);
                        let out = self.rt.run_block_y(k, l, bb, &self.scratch.full[..bb * l * h])?;
                        for (i, hbuf) in self.scratch.hidden[..b].iter_mut().enumerate() {
                            hbuf.copy_from_slice(&out[i * l * h..(i + 1) * l * h]);
                        }
                    }
                }
            }
            blk = end;
        }

        // release this step's tier pins (entries stay resident, evictable)
        for key in &step_pins {
            self.kv_tier.0.unpin(key);
        }

        // -- latent update ----------------------------------------------------
        for (i, m) in members.iter_mut().enumerate() {
            let Member { prep, acts, latent, step, steps_computed, .. } = m;
            *steps_computed += 1;
            Self::advance_latent(
                self.rt.schedule(),
                h,
                prep,
                acts,
                step,
                latent,
                &self.scratch.hidden[i],
            );
        }
        Ok(())
    }

    /// Loader-rung fallback: a loader job that died (injected fault) is
    /// re-gathered synchronously on the compute stream — correct but
    /// unpipelined, one rung down the degradation ladder.
    fn staged_fallback(
        &self,
        blk: usize,
        members: Vec<MemberGather>,
        mode: CacheMode,
        bb: usize,
    ) -> StagedBlock {
        self.rt.note_cache_degraded_loader();
        self.loader.gather_sync(blk, members, mode, bb)
    }

    /// Serve one cached block's K/V for the device loop: from the device
    /// tier when resident (a hit — **no upload at all**), else upload the
    /// staged pair once and offer it to the tier. Entries inserted here
    /// are pinned (recorded in `step_pins`) so a later block's insert
    /// cannot evict them before the step's pins are released.
    fn acquire_kv(
        rt: &ModelRuntime,
        tier: &mut KvDeviceTier<KvPair>,
        keys: &Option<Vec<KvKey>>,
        blk: usize,
        staged: &mut StagedBlock,
        dims: &[usize],
        step_pins: &mut Vec<KvKey>,
    ) -> Result<Rc<KvPair>> {
        let key = keys.as_ref().map(|ks| ks[blk]);
        if let Some(key) = &key {
            if let Some(kv) = tier.get(key) {
                rt.note_kv_dev_hit();
                return Ok(kv);
            }
        }
        rt.note_kv_dev_miss();
        let (kc, vc) = staged.kv_packed.take().expect("kv staged for non-resident block");
        let bytes = (kc.len() + vc.len()) * 4;
        let (kb, vb) = rt.upload_kv_pair(&kc, &vc, dims)?;
        match key {
            Some(key) => {
                let (kv, stored) = tier.insert(key, (kb, vb), bytes);
                if stored {
                    tier.pin(&key);
                    step_pins.push(key);
                }
                Ok(kv)
            }
            // multi-member batch (or tier disabled): one-shot buffers
            None => Ok(Rc::new((kb, vb))),
        }
    }

    // -- completion -----------------------------------------------------------

    fn complete_finished(&mut self, members: &mut Vec<Member>) {
        let total_steps = self.rt.config.steps;
        let mut i = 0;
        while i < members.len() {
            if members[i].step >= total_steps {
                let m = members.swap_remove(i);
                let remaining = members.len();
                self.finish_member(m, remaining, members);
            } else {
                i += 1;
            }
        }
    }

    fn finish_member(&self, m: Member, _remaining: usize, others: &mut [Member]) {
        if self.cfg.checkpoint_every_steps > 0 {
            remove_checkpoint(&self.checkpoint_dir(), m.prep.request.id);
        }
        let cfg = &self.rt.config;
        let latent = Tensor::from_vec(
            &[cfg.tokens, cfg.hidden],
            m.latent.data().to_vec(),
        )
        .expect("latent tensor");
        let decoder = self.rt.weights().decoder.clone();
        let mut timing = RequestTiming {
            queue: (m.joined - m.prep.request.arrival).as_secs_f64(),
            inference: m.joined.elapsed().as_secs_f64(),
            e2e: 0.0,
            interruptions: m.interruptions,
            steps_computed: m.steps_computed,
        };
        let arrival = m.prep.request.arrival;
        let id = m.prep.request.id;
        // terminal SSE event at the denoise boundary (postprocess still
        // runs, but no further step progress will ever be published)
        if m.prep.request.session.is_some() {
            self.shared.finish_progress(id);
        }
        let template_id = m.prep.request.template_id.clone();
        let ratio = m.prep.request.mask.ratio();
        let priority = m.prep.request.priority;
        let events = self.events.clone();
        let worker = self.id;
        let cpu_us = self.cfg.prepost_cpu_us;

        let work = move || {
            let image = postprocess(&latent, &decoder, cpu_us);
            timing.e2e = arrival.elapsed().as_secs_f64();
            let _ = events.send(WorkerEvent::Finished {
                id,
                worker,
                result: Ok(EditResponse {
                    id,
                    template_id,
                    image,
                    latent,
                    timing,
                    mask_ratio: ratio,
                    priority,
                }),
            });
        };

        match self.cfg.batching {
            BatchingPolicy::ContinuousDisaggregated => self.prepost.submit(work),
            _ => {
                // inline postprocess interrupts every remaining member
                for o in others.iter_mut() {
                    o.interruptions += 1;
                }
                work();
            }
        }
    }

    fn publish(&self, members: &[Member]) {
        self.shared.running.store(members.len(), Ordering::Relaxed);
        let masked: usize = members.iter().map(|m| m.prep.masked_count).sum();
        self.shared.running_masked.store(masked, Ordering::Relaxed);
        {
            let mut ratios = self.shared.running_ratios.lock().unwrap();
            ratios.clear();
            ratios.extend(members.iter().map(|m| m.prep.request.mask.ratio()));
        }
        let t = self.rt.transfer_totals();
        self.shared.h2d_ops.store(t.h2d_ops, Ordering::Relaxed);
        self.shared.d2h_ops.store(t.d2h_ops, Ordering::Relaxed);
        self.shared.h2d_bytes.store(t.h2d_bytes, Ordering::Relaxed);
        self.shared.d2h_bytes.store(t.d2h_bytes, Ordering::Relaxed);
        self.shared.kv_h2d_bytes.store(t.kv_h2d_bytes, Ordering::Relaxed);
        self.shared.kv_dev_hits.store(t.kv_dev_hits, Ordering::Relaxed);
        self.shared.kv_dev_misses.store(t.kv_dev_misses, Ordering::Relaxed);
        self.shared
            .kv_prefetch_overlap_us
            .store(t.kv_prefetch_overlap_us, Ordering::Relaxed);
        // degradation-ladder counters: the device rung folds in the KV
        // tier's rejected uploads (tracked tier-side, engine-confined)
        let kv_faults = self.kv_tier.0.stats().upload_faults;
        self.shared
            .cache_degraded_disk
            .store(t.cache_degraded_disk, Ordering::Relaxed);
        self.shared
            .cache_degraded_device
            .store(t.cache_degraded_device + kv_faults, Ordering::Relaxed);
        self.shared
            .cache_degraded_loader
            .store(t.cache_degraded_loader, Ordering::Relaxed);
        // session rounds: one progress event per member per step boundary,
        // with the Algo-2 per-step cost as the remaining-time estimator
        for m in members.iter().filter(|m| m.prep.request.session.is_some()) {
            let cfg = &self.rt.config;
            let total = cfg.steps;
            let remaining = total.saturating_sub(m.step);
            let n = m.cached_bucket.min(cfg.tokens);
            let costs = self.lat_model.step_costs(cfg, n, members.len(), self.cfg.cache_mode);
            let per_step = if n >= cfg.tokens || !self.mask_aware() {
                pipeline::full_latency(&costs)
            } else {
                pipeline::plan(&costs).latency
            };
            let est_ms = (per_step * remaining as f64 * 1e3).ceil() as u64;
            let data = m.latent.data();
            let len = data.len().max(1) as f32;
            let mean = data.iter().sum::<f32>() / len;
            let rms = (data.iter().map(|v| v * v).sum::<f32>() / len).sqrt();
            self.shared.push_progress(
                m.prep.request.id,
                m.step as u32,
                total as u32,
                est_ms,
                mean,
                rms,
            );
        }
    }
}

/// Wait for the copy stream to deliver block `blk` (a bubble iff the DP
/// mispredicts). `None` means the loader job died (injected fault): the
/// caller degrades to a synchronous gather on the compute stream.
fn take_staged(
    now: &mut [Option<StagedBlock>],
    rx: &mut [Option<Receiver<StagedBlock>>],
    blk: usize,
) -> Option<StagedBlock> {
    match now[blk].take() {
        Some(s) => Some(s),
        None => rx[blk].take().expect("staged rx").recv().ok(),
    }
}

/// Non-blocking probe used by the prefetch stream: block `blk`'s staged
/// data if the copy stream has already delivered it.
fn try_staged(
    now: &mut [Option<StagedBlock>],
    rx: &mut [Option<Receiver<StagedBlock>>],
    blk: usize,
) -> Option<StagedBlock> {
    if now[blk].is_some() {
        return now[blk].take();
    }
    let ready = rx[blk].as_ref().and_then(|r| r.try_recv().ok());
    if ready.is_some() {
        rx[blk] = None;
    }
    ready
}

fn gather_rows(src: &[f32], h: usize, ids: &[usize], out: &mut [f32]) {
    for (i, &id) in ids.iter().enumerate() {
        out[i * h..(i + 1) * h].copy_from_slice(&src[id * h..(id + 1) * h]);
    }
}

fn scatter_rows(dst: &mut [f32], h: usize, ids: &[usize], src: &[f32]) {
    for (i, &id) in ids.iter().enumerate() {
        dst[id * h..(id + 1) * h].copy_from_slice(&src[i * h..(i + 1) * h]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg;

    /// Simulate the per-step scratch traffic of one engine shape.
    fn simulate_step(s: &mut StepScratch, b: usize, bb: usize, n: usize, l: usize, h: usize) {
        StepScratch::resize_tracked(&mut s.packed, bb * n * h, &mut s.grows);
        StepScratch::resize_tracked(&mut s.full, bb * l * h, &mut s.grows);
        if s.hidden.len() < b {
            s.grows += 1;
            s.hidden.resize_with(b, Vec::new);
        }
        for i in 0..b {
            let StepScratch { hidden, grows, .. } = s;
            if hidden[i].capacity() < l * h {
                *grows += 1;
            }
            hidden[i].clear();
            hidden[i].resize(l * h, 0.0);
        }
        s.compute.clear();
        s.compute.resize(b, true);
    }

    #[test]
    fn scratch_arena_stops_growing_once_warm() {
        // property: replaying any step-shape sequence a second time must
        // not grow the arena — the hot loop is allocation-free once warm.
        prop_check("scratch arena no per-step growth", 50, |rng: &mut Pcg| {
            let mut s = StepScratch::default();
            let (l, h) = (16 + rng.below(16), 4 + rng.below(8));
            let shapes: Vec<(usize, usize, usize)> = (0..4 + rng.below(4))
                .map(|_| {
                    let b = 1 + rng.below(8);
                    let bb = b.next_power_of_two();
                    let n = 1 + rng.below(l);
                    (b, bb, n)
                })
                .collect();
            for &(b, bb, n) in &shapes {
                simulate_step(&mut s, b, bb, n, l, h);
            }
            let warm = s.grows;
            for _ in 0..3 {
                for &(b, bb, n) in &shapes {
                    simulate_step(&mut s, b, bb, n, l, h);
                }
            }
            prop_assert!(
                s.grows == warm,
                "arena grew after warmup: {} -> {} (shapes {:?})",
                warm,
                s.grows,
                shapes
            );
            Ok(())
        });
    }

    #[test]
    fn snapshot_collects_real_mask_ratios() {
        use crate::engine::request::EditRequest;
        use crate::model::MaskSpec;

        let q = WorkerQueue::new();
        q.push_raw(EditRequest::new(1, "t", MaskSpec::new(vec![0, 1], 16), 1));
        let shared = WorkerShared::default();
        shared.running.store(1, Ordering::Relaxed);
        *shared.running_ratios.lock().unwrap() = vec![0.5];
        let snap = WorkerSnapshot::collect(3, &q, &shared);
        assert_eq!(snap.worker_id, 3);
        assert_eq!(snap.queued, 1);
        assert_eq!(snap.running, 1);
        let mut ratios = snap.mask_ratios;
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ratios, vec![2.0 / 16.0, 0.5], "queued + running ratios");
    }

    #[test]
    fn progress_buffer_drops_oldest_never_grows_unbounded() {
        let shared = WorkerShared::default();
        // a slow consumer: push far more events than the cap
        for step in 0..(PROGRESS_EVENT_CAP as u32 * 3) {
            shared.push_progress(7, step, 100, 10, 0.0, 0.0);
        }
        let (events, done) = shared.progress_since(7, 0).expect("buffer exists");
        assert!(!done);
        assert_eq!(events.len(), PROGRESS_EVENT_CAP, "bounded buffer");
        // oldest dropped: the retained window is the most recent events,
        // still strictly ordered by seq
        assert_eq!(events.first().unwrap().step, PROGRESS_EVENT_CAP as u32 * 2);
        for w in events.windows(2) {
            assert!(w[1].seq > w[0].seq && w[1].step > w[0].step);
        }
        // cursor-based resume skips what was already seen
        let cursor = events[events.len() - 2].seq + 1;
        let (tail, _) = shared.progress_since(7, cursor).unwrap();
        assert_eq!(tail.len(), 1);
    }

    #[test]
    fn progress_terminal_event_and_bounded_done_retention() {
        let shared = WorkerShared::default();
        shared.push_progress(1, 0, 8, 80, 0.0, 0.0);
        shared.finish_progress(1);
        let (events, done) = shared.progress_since(1, 0).unwrap();
        assert!(done);
        assert!(events.last().unwrap().done, "terminal event present");
        // events after done are ignored
        shared.push_progress(1, 5, 8, 30, 0.0, 0.0);
        let (events2, _) = shared.progress_since(1, 0).unwrap();
        assert_eq!(events2.len(), events.len());
        // unwatched finished rounds are evicted beyond the retention cap
        for id in 10..(10 + PROGRESS_DONE_KEEP as u64 + 5) {
            shared.push_progress(id, 0, 8, 80, 0.0, 0.0);
            shared.finish_progress(id);
        }
        assert!(shared.progress_rounds() <= PROGRESS_DONE_KEEP + 1);
        assert!(shared.progress_since(1, 0).is_none(), "oldest done round evicted");
        // explicit drop releases immediately
        let before = shared.progress_rounds();
        shared.drop_progress(10 + PROGRESS_DONE_KEEP as u64 + 4);
        assert_eq!(shared.progress_rounds(), before - 1);
    }

    #[test]
    fn gather_scatter_roundtrip_is_identity() {
        // the device-chain identity the step loop exploits: scatter(ids,
        // out) then gather(ids) returns out unchanged
        let h = 4;
        let l = 8;
        let ids = [5usize, 1, 6];
        let mut hidden: Vec<f32> = (0..l * h).map(|i| i as f32).collect();
        let out: Vec<f32> = (0..ids.len() * h).map(|i| -(i as f32)).collect();
        scatter_rows(&mut hidden, h, &ids, &out);
        let mut back = vec![0f32; ids.len() * h];
        gather_rows(&hidden, h, &ids, &mut back);
        assert_eq!(back, out);
    }
}
