//! The worker engine (paper §4.2/§4.3): step loop, continuous batching
//! with disaggregated pre/post-processing, and the baseline modes.

pub mod prepost;
pub mod queue;
pub mod request;
pub mod teacache;
pub mod worker;

pub use queue::{Submitter, WorkerQueue};
pub use request::{
    EditError, EditRequest, EditRequestBuilder, EditResponse, RequestTiming, WorkerEvent,
};
pub use worker::{Worker, WorkerSnapshot};
