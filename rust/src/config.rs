//! Configuration: model presets (mirroring python/compile/configs.py via
//! artifacts/manifest.json), engine and cluster settings.

use std::path::PathBuf;

use crate::faults::FaultPlan;
use crate::qos::QosConfig;

/// Static description of a mini diffusion model (loaded from the manifest;
/// the python side is the single source of truth).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub latent_hw: usize,
    pub tokens: usize,
    pub hidden: usize,
    pub heads: usize,
    pub blocks: usize,
    pub steps: usize,
    pub token_buckets: Vec<usize>,
    pub paper_analogue: String,
}

impl ModelConfig {
    /// Smallest token bucket covering `k` masked tokens (falls back to the
    /// full sequence when the mask exceeds every bucket).
    pub fn bucket_for(&self, k: usize) -> usize {
        for &b in &self.token_buckets {
            if b >= k {
                return b;
            }
        }
        self.tokens
    }

    /// All compiled token counts: buckets plus the full block.
    pub fn all_token_counts(&self) -> Vec<usize> {
        let mut v = self.token_buckets.clone();
        v.push(self.tokens);
        v
    }
}

/// Which baseline/system an engine runs as (paper §6 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemKind {
    /// InstGenIE: mask-aware + bubble-free pipeline + continuous batching.
    InstGenIE,
    /// HuggingFace Diffusers: full-image recompute + static batching.
    Diffusers,
    /// FISEdit: mask-aware sparse compute, but batch size 1 only.
    FisEdit,
    /// TeaCache: step-skipping via timestep-embedding distance; full
    /// recompute on non-skipped steps, static batching.
    TeaCache,
}

impl SystemKind {
    pub fn parse(s: &str) -> Option<SystemKind> {
        match s.to_ascii_lowercase().as_str() {
            "instgenie" => Some(SystemKind::InstGenIE),
            "diffusers" => Some(SystemKind::Diffusers),
            "fisedit" => Some(SystemKind::FisEdit),
            "teacache" => Some(SystemKind::TeaCache),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::InstGenIE => "instgenie",
            SystemKind::Diffusers => "diffusers",
            SystemKind::FisEdit => "fisedit",
            SystemKind::TeaCache => "teacache",
        }
    }
}

/// Batching policy of a worker (§4.3 / §6.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchingPolicy {
    /// Fixed running batch until every member finishes (baselines [9, 19]).
    Static,
    /// Step-level join/leave, but pre/post run inline on the engine thread
    /// (the paper's strawman, Fig. 10-Top).
    ContinuousInline,
    /// Step-level join/leave with pre/post disaggregated to a separate
    /// pool (InstGenIE, Fig. 10-Bottom).
    ContinuousDisaggregated,
}

/// Activation-cache mode (§3.1): cache the block outputs Y (default) or
/// the K/V projections (Fig. 7 alternative, 2x cache for slightly better
/// latency at small mask ratios).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    CacheY,
    CacheKV,
}

/// Per-worker engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub system: SystemKind,
    pub batching: BatchingPolicy,
    pub cache_mode: CacheMode,
    pub max_batch: usize,
    /// Simulated DRAM->HBM bandwidth for cache loading, bytes/sec.
    /// Calibrated so the load:compute latency ratio matches the paper's
    /// H800 + PCIe Gen5 regime (DESIGN.md "Substitutions").
    pub sim_bandwidth: f64,
    /// Host-tier cache budget in bytes before spilling to disk (LRU).
    pub host_cache_budget: usize,
    /// Directory for disk-tier spill files.
    pub spill_dir: PathBuf,
    /// Device-resident step loop (default): block outputs chain
    /// device-to-device inside a contiguous same-mode run, with one
    /// upload per run start and one download per run end. `false` runs
    /// the host-round-trip reference loop (2 transfers per block) — the
    /// golden tests hold the two bit-identical, and the overhead bench
    /// uses it as the before/after baseline.
    pub device_resident: bool,
    /// HBM budget (bytes) of the device-resident KV working set — the
    /// upload-once LRU over staged K/V buffers that lets a warm
    /// template's cache-KV blocks run with zero per-step host→device
    /// transfers. `0` disables the tier (`--no-kv-device-tier`): every
    /// cached block re-uploads its staged K/V each step, the pre-tier
    /// behavior.
    pub kv_device_budget_bytes: usize,
    /// Disable the bubble-free DP and always use the cache for every block
    /// (the strawman pipeline of Fig. 9-Middle) — for ablations.
    pub force_all_cached: bool,
    /// Disable overlap entirely (naive loading, Fig. 9-Top) — ablations.
    pub naive_loading: bool,
    /// TeaCache skip threshold (timestep-embedding L1 distance).
    pub teacache_threshold: f64,
    /// Threads in the pre/post-processing pool (disaggregated mode).
    pub prepost_threads: usize,
    /// How long a request whose template is still registering may wait
    /// parked at the worker before failing with `Timeout`
    /// (submit-during-registration queues up to this long), in ms.
    pub registration_wait_ms: u64,
    /// Extra CPU work per pre/post op, microseconds (models the paper's
    /// serialization/deserialization cost; §6.4 measures its interference).
    pub prepost_cpu_us: u64,
    /// Quality-of-service: priority-ordered queues with aging,
    /// step-boundary preemption, deadline expiry, and admission control.
    pub qos: QosConfig,
    /// Deterministic fault injection (`--faults <spec>`); `None` (the
    /// default) compiles the injection points down to a null check.
    pub faults: Option<FaultPlan>,
    /// Spill a step-boundary latent checkpoint for every running member
    /// each time its step count crosses a multiple of this, so a crashed
    /// worker resumes the batch from the last checkpoint instead of step
    /// 0 (the engine is deterministic, so the resumed run is
    /// bit-identical). `0` disables checkpointing.
    pub checkpoint_every_steps: usize,
}

impl EngineConfig {
    pub fn instgenie() -> EngineConfig {
        EngineConfig {
            system: SystemKind::InstGenIE,
            batching: BatchingPolicy::ContinuousDisaggregated,
            cache_mode: CacheMode::CacheY,
            max_batch: 8,
            // Calibrated so per-block load latency ~ per-block cached
            // compute latency at the trace-average mask ratio (~0.1-0.2),
            // matching the paper's H800 + PCIe Gen5 regime where naive
            // loading costs ~+102% vs ideal (Fig. 4-Left). See
            // EXPERIMENTS.md "Bandwidth calibration".
            sim_bandwidth: 384.0 * 1024.0 * 1024.0,
            host_cache_budget: 512 << 20,
            spill_dir: PathBuf::from("artifacts/cache_spill"),
            device_resident: true,
            kv_device_budget_bytes: 256 << 20,
            force_all_cached: false,
            naive_loading: false,
            teacache_threshold: 0.05,
            prepost_threads: 2,
            registration_wait_ms: 30_000,
            prepost_cpu_us: 2_000,
            qos: QosConfig::standard(),
            faults: None,
            checkpoint_every_steps: 0,
        }
    }

    pub fn for_system(system: SystemKind) -> EngineConfig {
        let mut c = EngineConfig::instgenie();
        c.system = system;
        match system {
            SystemKind::InstGenIE => {}
            SystemKind::Diffusers => {
                c.batching = BatchingPolicy::Static;
            }
            SystemKind::FisEdit => {
                c.batching = BatchingPolicy::Static;
                c.max_batch = 1;
            }
            SystemKind::TeaCache => {
                c.batching = BatchingPolicy::Static;
            }
        }
        c
    }
}

/// Cluster-level configuration (scheduler + N workers).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    pub engine: EngineConfig,
}

impl ClusterConfig {
    pub fn new(workers: usize, engine: EngineConfig) -> ClusterConfig {
        ClusterConfig { workers, engine }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            latent_hw: 8,
            tokens: 64,
            hidden: 64,
            heads: 4,
            blocks: 4,
            steps: 8,
            token_buckets: vec![4, 8, 16, 32],
            paper_analogue: String::new(),
        }
    }

    #[test]
    fn bucket_selection() {
        let c = cfg();
        assert_eq!(c.bucket_for(1), 4);
        assert_eq!(c.bucket_for(4), 4);
        assert_eq!(c.bucket_for(5), 8);
        assert_eq!(c.bucket_for(33), 64); // falls to full sequence
        assert_eq!(c.bucket_for(64), 64);
    }

    #[test]
    fn system_kind_parse() {
        assert_eq!(SystemKind::parse("InstGenIE"), Some(SystemKind::InstGenIE));
        assert_eq!(SystemKind::parse("diffusers"), Some(SystemKind::Diffusers));
        assert_eq!(SystemKind::parse("nope"), None);
        assert_eq!(SystemKind::FisEdit.name(), "fisedit");
    }

    #[test]
    fn baseline_configs_match_paper_constraints() {
        let f = EngineConfig::for_system(SystemKind::FisEdit);
        assert_eq!(f.max_batch, 1); // FISEdit cannot batch different masks
        let d = EngineConfig::for_system(SystemKind::Diffusers);
        assert_eq!(d.batching, BatchingPolicy::Static);
    }
}
