//! Quality-of-service: priority classes, deadline-aware admission control,
//! and overload shedding on top of step-level continuous batching.
//!
//! The paper gives the serving stack two mechanisms this module turns into
//! policy: batch membership changes at one-denoise-step granularity
//! (§4.3), and Algorithm 2's cost model predicts a request's completion
//! latency from its mask ratio and cache residency (§4.4). A [`Priority`]
//! orders requests inside every worker queue (strict priority with an
//! aging credit so `Batch` always eventually runs), deadlines bound how
//! long a request may wait before it is shed instead of burning denoise
//! steps, and the [`AdmissionController`] rejects work up front — with a
//! `Retry-After` estimate — once the backlog makes the request's deadline
//! (or its class's wait bound) infeasible. Under the bursty, heavy-tailed
//! traffic of §2.2 this keeps interactive edits fast while overload
//! degrades into bounded shedding rather than unbounded queues.

use std::time::Duration;

use crate::cache::LatencyModel;
use crate::config::{CacheMode, ModelConfig};
use crate::scheduler::{Book, MaskAware, Outstanding, RouteCtx};

/// Number of request classes (array index = [`Priority::rank`]).
pub const CLASS_COUNT: usize = 3;

/// Request class: who is waiting for the edit.
///
/// Ordering is urgency: `Interactive < Standard < Batch`, so
/// `min_by_key(priority)` picks the most urgent request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// A user is watching the edit render (the paper's motivating
    /// workload): lowest latency, may preempt lower classes.
    Interactive,
    /// Ordinary API traffic.
    #[default]
    Standard,
    /// Bulk/offline jobs: throughput only, runs on leftover capacity.
    Batch,
}

impl Priority {
    /// All classes, most urgent first (stable report order).
    pub const ALL: [Priority; CLASS_COUNT] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// 0 = most urgent. Indexes per-class arrays.
    pub fn rank(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Some(Priority::Interactive),
            "standard" => Some(Priority::Standard),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// Effective class rank after `waited` in queue: one level of aging credit
/// per `aging_ms`, so a starved `Batch` request eventually outranks fresh
/// `Interactive` arrivals (strict priority would starve it forever).
/// `aging_ms == 0` disables aging (rank is the static class).
pub fn effective_rank(rank: usize, waited: Duration, aging_ms: u64) -> i64 {
    if aging_ms == 0 {
        return rank as i64;
    }
    rank as i64 - (waited.as_millis() as u64 / aging_ms) as i64
}

/// Per-class queue depth snapshot (stats endpoints + scheduler).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassDepth {
    pub queued: usize,
    /// Age of the oldest queued request of this class, seconds.
    pub oldest_wait_secs: f64,
}

/// QoS knobs carried in the engine config.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Master switch: priority-ordered queues, preemption, deadline
    /// expiry, and admission control. Off = the FIFO baseline.
    pub enabled: bool,
    /// Aging credit quantum for [`effective_rank`] (anti-starvation).
    pub aging_ms: u64,
    /// Admission: hard cap on outstanding (queued + running) requests
    /// cluster-wide; beyond it submissions are shed with `Overloaded`.
    pub max_pending: usize,
    /// Admission: per-class bound on the *estimated* completion latency
    /// (seconds, indexed by [`Priority::rank`]); `INFINITY` disables the
    /// bound for that class.
    pub class_wait_bounds: [f64; CLASS_COUNT],
}

impl QosConfig {
    /// QoS on, with permissive limits: priorities, aging and preemption
    /// are active, but nothing is shed until the pending cap is hit.
    pub fn standard() -> QosConfig {
        QosConfig {
            enabled: true,
            aging_ms: 2_000,
            max_pending: 4_096,
            class_wait_bounds: [f64::INFINITY; CLASS_COUNT],
        }
    }

    /// The FIFO baseline: no reordering, no preemption, no shedding.
    pub fn disabled() -> QosConfig {
        QosConfig { enabled: false, ..QosConfig::standard() }
    }
}

/// Admission verdict for one request against the current cluster state.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    Admit,
    /// Over capacity (pending cap or class wait bound): shed now, retry
    /// after the backlog is estimated to have drained enough.
    Overloaded { retry_after: f64, estimate: f64 },
    /// Even the best worker cannot finish before the request's deadline.
    DeadlineInfeasible { estimate: f64, deadline: f64 },
}

/// Deadline-aware admission control (tentpole part 3): reuses the
/// scheduler's Algorithm-2 cost model — calibrated latency regressions +
/// the pipeline DP + the live queue snapshot — to estimate the request's
/// completion latency on its best worker, then sheds infeasible or
/// over-capacity work up front instead of letting queues grow unboundedly.
pub struct AdmissionController {
    cost: MaskAware,
    limits: QosConfig,
}

impl AdmissionController {
    pub fn new(
        cfg: ModelConfig,
        lat: LatencyModel,
        mode: CacheMode,
        max_batch: usize,
        limits: QosConfig,
    ) -> AdmissionController {
        AdmissionController { cost: MaskAware::new(cfg, lat, mode, max_batch), limits }
    }

    /// Estimated completion latency (seconds) of `req` on its best
    /// candidate worker: Algorithm 2's backlog cost with the request
    /// appended, plus the cache-load penalty where its template is cold —
    /// the same [`MaskAware::best_completion`] the routing policies use,
    /// so admission and routing can never diverge.
    pub fn estimate(&self, req: &Outstanding, book: &Book, ctx: &RouteCtx) -> f64 {
        self.cost.best_completion(req, book, ctx).1
    }

    /// Assess one submission. `deadline` is the time remaining until the
    /// request's deadline (None = no deadline).
    pub fn assess(
        &self,
        req: &Outstanding,
        deadline: Option<Duration>,
        book: &Book,
        ctx: &RouteCtx,
    ) -> Admission {
        let estimate = self.estimate(req, book, ctx);
        if let Some(d) = deadline {
            let d = d.as_secs_f64();
            if estimate > d {
                return Admission::DeadlineInfeasible { estimate, deadline: d };
            }
        }
        let pending: usize = book.iter().map(|lane| lane.len()).sum();
        if pending >= self.limits.max_pending {
            return Admission::Overloaded { retry_after: estimate.max(1e-3), estimate };
        }
        let bound = self.limits.class_wait_bounds[req.priority.rank()];
        if estimate > bound {
            return Admission::Overloaded { retry_after: (estimate - bound).max(1e-3), estimate };
        }
        Admission::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            latent_hw: 8,
            tokens: 64,
            hidden: 64,
            heads: 4,
            blocks: 4,
            steps: 8,
            token_buckets: vec![4, 8, 16, 32],
            paper_analogue: String::new(),
        }
    }

    fn ctl(limits: QosConfig) -> AdmissionController {
        let lat = LatencyModel::nominal(1e9, 1e8);
        AdmissionController::new(cfg(), lat, CacheMode::CacheY, 8, limits)
    }

    fn o(id: u64, masked: usize, priority: Priority) -> Outstanding {
        Outstanding { id, masked_tokens: masked, remaining_steps: 8, priority }
    }

    #[test]
    fn priority_order_and_labels() {
        assert!(Priority::Interactive < Priority::Standard);
        assert!(Priority::Standard < Priority::Batch);
        assert_eq!(Priority::Interactive.rank(), 0);
        assert_eq!(Priority::Batch.rank(), 2);
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.label()), Some(p));
        }
        assert_eq!(Priority::parse("Interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse("nope"), None);
        assert_eq!(Priority::default(), Priority::Standard);
    }

    #[test]
    fn aging_credit_promotes_waiters() {
        // fresh batch request sits two levels below interactive
        assert_eq!(effective_rank(2, Duration::ZERO, 1000), 2);
        // after one quantum it matches standard, after two interactive
        assert_eq!(effective_rank(2, Duration::from_millis(1000), 1000), 1);
        assert_eq!(effective_rank(2, Duration::from_millis(2500), 1000), 0);
        // and keeps climbing, so it eventually beats any fresh arrival
        assert!(effective_rank(2, Duration::from_millis(9000), 1000) < 0);
        // aging disabled -> static rank
        assert_eq!(effective_rank(2, Duration::from_secs(60), 0), 2);
    }

    #[test]
    fn admits_when_under_limits() {
        let c = ctl(QosConfig::standard());
        let book = vec![vec![], vec![]];
        let verdict = c.assess(
            &o(1, 8, Priority::Standard),
            None,
            &book,
            &RouteCtx::default(),
        );
        assert_eq!(verdict, Admission::Admit);
    }

    #[test]
    fn pending_cap_sheds_with_retry_after() {
        let mut limits = QosConfig::standard();
        limits.max_pending = 2;
        let c = ctl(limits);
        let book = vec![vec![o(1, 8, Priority::Standard)], vec![o(2, 8, Priority::Standard)]];
        match c.assess(&o(3, 8, Priority::Standard), None, &book, &RouteCtx::default()) {
            Admission::Overloaded { retry_after, estimate } => {
                assert!(retry_after > 0.0);
                assert!(estimate > 0.0);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn class_wait_bound_sheds_only_the_bounded_class() {
        let mut limits = QosConfig::standard();
        // interactive must finish in ~0 seconds: always infeasible here
        limits.class_wait_bounds[Priority::Interactive.rank()] = 1e-9;
        let c = ctl(limits);
        let book = vec![vec![o(1, 32, Priority::Standard)]];
        let ctx = RouteCtx::default();
        assert!(matches!(
            c.assess(&o(2, 8, Priority::Interactive), None, &book, &ctx),
            Admission::Overloaded { .. }
        ));
        // the unbounded class still gets in
        assert_eq!(c.assess(&o(3, 8, Priority::Batch), None, &book, &ctx), Admission::Admit);
    }

    #[test]
    fn infeasible_deadline_is_rejected_up_front() {
        let c = ctl(QosConfig::standard());
        let book = vec![vec![o(1, 64, Priority::Standard); 8]];
        let tight = Some(Duration::from_nanos(1));
        match c.assess(&o(2, 8, Priority::Interactive), tight, &book, &RouteCtx::default()) {
            Admission::DeadlineInfeasible { estimate, deadline } => {
                assert!(estimate > deadline);
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        // a generous deadline admits
        let loose = Some(Duration::from_secs(3600));
        assert_eq!(
            c.assess(&o(3, 8, Priority::Interactive), loose, &book, &RouteCtx::default()),
            Admission::Admit
        );
    }

    #[test]
    fn estimate_prefers_the_lighter_worker() {
        let c = ctl(QosConfig::standard());
        let heavy = vec![o(1, 64, Priority::Standard); 8];
        let two_workers = vec![heavy.clone(), vec![]];
        let one_worker = vec![heavy];
        let req = o(9, 8, Priority::Standard);
        let solo = c.estimate(&req, &two_workers, &RouteCtx::default());
        let stuck = c.estimate(&req, &one_worker, &RouteCtx::default());
        assert!(solo < stuck, "an empty worker must lower the best estimate");
    }
}
