//! Block executor: the bridge between the coordinator's step loop and the
//! AOT-compiled XLA programs.
//!
//! One `ModelRuntime` per (worker, model): it owns the PJRT client handle,
//! the device-resident weights, and the schedule/embedding tables. Two
//! call families:
//!
//! - `run_block_*` — host-slice in, host-vec out. One upload + one
//!   download per call; the reference path and the registration trace.
//! - `run_block_*_dev` — `PjRtBuffer` in, `PjRtBuffer` out. Block i+1
//!   consumes block i's output buffer directly (array-root artifacts,
//!   manifest v4), so a contiguous run of blocks costs one upload and one
//!   download total. The worker's device-resident step loop lives on
//!   these.
//!
//! Program lookups go through a pre-resolved table indexed by
//! (kind, token count, batch bucket) — filled at `warmup` (or first use),
//! so the hot loop does no mutex/hash/string work. Host<->device
//! activation traffic is counted per runtime (`transfer_totals`), which
//! is how the overhead bench proves the "<= 2 transfers per contiguous
//! same-mode run" invariant.

use std::cell::{Cell, RefCell};
use std::sync::Arc;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::client::{buffer_to_vec, literal_f32, tuple_to_vecs, Client};
use super::manifest::{ArtifactKind, ArtifactRoot, Manifest, ModelManifest};
use super::weights::{DeviceWeights, HostWeights};
use crate::config::ModelConfig;
use crate::model::Schedule;

/// Executable handle + metadata for one grid entry.
#[derive(Clone)]
struct Program {
    exe: Arc<xla::PjRtLoadedExecutable>,
    root: ArtifactRoot,
}

/// Pre-resolved program table: `(kind, n, batch) -> Program` by direct
/// index, no locks or hashing. Slots fill at `warmup` or on first lazy
/// use; shapes outside the grid fall back to the manifest lookup.
struct ProgramTable {
    token_counts: Vec<usize>,
    batch_buckets: Vec<usize>,
    slots: Vec<Option<Program>>,
}

impl ProgramTable {
    fn new(config: &ModelConfig, batch_buckets: &[usize]) -> ProgramTable {
        let token_counts = config.all_token_counts();
        let slots = vec![None; 3 * token_counts.len() * batch_buckets.len()];
        ProgramTable { token_counts, batch_buckets: batch_buckets.to_vec(), slots }
    }

    fn index(&self, kind: ArtifactKind, n: usize, batch: usize) -> Option<usize> {
        let k = match kind {
            ArtifactKind::BlockY => 0,
            ArtifactKind::BlockKV => 1,
            ArtifactKind::BlockReg => 2,
        };
        let t = self.token_counts.iter().position(|&c| c == n)?;
        let b = self.batch_buckets.iter().position(|&c| c == batch)?;
        Some((k * self.token_counts.len() + t) * self.batch_buckets.len() + b)
    }
}

/// Cumulative host<->device activation traffic of one runtime. Weights
/// (uploaded once at load) are excluded: this counts exactly the per-step
/// coordinator traffic the device-resident loop minimizes. The `kv_*`
/// fields break out the device KV tier: staged-K/V upload bytes (a
/// subset of `h2d_bytes`), tier hits/misses, and how much upload time
/// the second copy stream hid under compute (`kv_prefetch_overlap_us`,
/// integer micros so the struct stays `Eq`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferTotals {
    pub h2d_ops: u64,
    pub d2h_ops: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// Staged-K/V bytes uploaded host→device (0 in warm steady state).
    pub kv_h2d_bytes: u64,
    /// Cache-KV blocks served from the device KV tier (no upload).
    pub kv_dev_hits: u64,
    /// Cache-KV blocks whose K/V had to be uploaded this step.
    pub kv_dev_misses: u64,
    /// Upload time hidden by prefetching block i+1's K/V while block i
    /// computes (microseconds).
    pub kv_prefetch_overlap_us: u64,
    /// Degradation-ladder events: a disk-tier read error, corruption, or
    /// checksum mismatch forced a full template recompute.
    pub cache_degraded_disk: u64,
    /// Device-KV-tier upload/retention failures (blocks fell back to
    /// per-step re-upload from host).
    pub cache_degraded_device: u64,
    /// Loader staging jobs that died; the block was gathered
    /// synchronously from the host store instead.
    pub cache_degraded_loader: u64,
}

#[derive(Default)]
struct TransferCounters {
    h2d_ops: Cell<u64>,
    d2h_ops: Cell<u64>,
    h2d_bytes: Cell<u64>,
    d2h_bytes: Cell<u64>,
    kv_h2d_bytes: Cell<u64>,
    kv_dev_hits: Cell<u64>,
    kv_dev_misses: Cell<u64>,
    kv_prefetch_overlap_us: Cell<u64>,
    cache_degraded_disk: Cell<u64>,
    cache_degraded_device: Cell<u64>,
    cache_degraded_loader: Cell<u64>,
}

impl TransferCounters {
    fn count_h2d(&self, floats: usize) {
        self.h2d_ops.set(self.h2d_ops.get() + 1);
        self.h2d_bytes.set(self.h2d_bytes.get() + 4 * floats as u64);
    }

    fn count_d2h(&self, floats: usize) {
        self.d2h_ops.set(self.d2h_ops.get() + 1);
        self.d2h_bytes.set(self.d2h_bytes.get() + 4 * floats as u64);
    }

    fn count_kv_h2d(&self, floats: usize) {
        self.kv_h2d_bytes.set(self.kv_h2d_bytes.get() + 4 * floats as u64);
    }

    fn totals(&self) -> TransferTotals {
        TransferTotals {
            h2d_ops: self.h2d_ops.get(),
            d2h_ops: self.d2h_ops.get(),
            h2d_bytes: self.h2d_bytes.get(),
            d2h_bytes: self.d2h_bytes.get(),
            kv_h2d_bytes: self.kv_h2d_bytes.get(),
            kv_dev_hits: self.kv_dev_hits.get(),
            kv_dev_misses: self.kv_dev_misses.get(),
            kv_prefetch_overlap_us: self.kv_prefetch_overlap_us.get(),
            cache_degraded_disk: self.cache_degraded_disk.get(),
            cache_degraded_device: self.cache_degraded_device.get(),
            cache_degraded_loader: self.cache_degraded_loader.get(),
        }
    }
}

/// Per-model runtime: compiled programs + weights + schedule.
pub struct ModelRuntime {
    client: Arc<Client>,
    manifest: ModelManifest,
    pub config: ModelConfig,
    batch_buckets: Vec<usize>,
    host_weights: HostWeights,
    device_weights: DeviceWeights,
    schedule: Schedule,
    table: RefCell<ProgramTable>,
    transfers: TransferCounters,
}

// SAFETY: ModelRuntime transitively holds `Rc`-based PJRT handles, so it
// is only sound to *move* a runtime (together with the sole Arc<Client>
// strong reference it was built from) onto another thread and use it
// exclusively there. The engine upholds this: each Worker constructs its
// own Client + ModelRuntime pair via `ModelRuntime::create`, moves them
// into the worker thread, and never shares them. Loader / pre-post
// threads operate on plain host data only. (The RefCell program table
// and Cell transfer counters are single-thread state under the same
// invariant; the runtime is deliberately !Sync.)
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    /// Construct a private client + runtime pair (the only safe way to
    /// build a runtime that will move to a worker thread).
    pub fn create(artifact_dir: &str, model: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = Arc::new(Client::cpu()?);
        ModelRuntime::load(client, &manifest, model)
    }

    /// Load a model runtime from the manifest (lazy program compilation).
    pub fn load(client: Arc<Client>, manifest: &Manifest, model: &str) -> Result<ModelRuntime> {
        let man = manifest.model(model)?.clone();
        let config = man.config.clone();
        let host_weights = HostWeights::load(&man)?;
        let device_weights = DeviceWeights::upload(&client, &host_weights)?;
        let schedule = Schedule::new(host_weights.sigmas.clone());
        let table = RefCell::new(ProgramTable::new(&config, &manifest.batch_buckets));
        Ok(ModelRuntime {
            client,
            manifest: man,
            config,
            batch_buckets: manifest.batch_buckets.clone(),
            host_weights,
            device_weights,
            schedule,
            table,
            transfers: TransferCounters::default(),
        })
    }

    /// Smallest compiled batch bucket covering `b` members.
    pub fn batch_bucket_for(&self, b: usize) -> usize {
        for &bb in &self.batch_buckets {
            if bb >= b {
                return bb;
            }
        }
        *self.batch_buckets.last().unwrap_or(&1)
    }

    /// Largest compiled batch bucket (engine max-batch clamp).
    pub fn max_batch_bucket(&self) -> usize {
        *self.batch_buckets.last().unwrap_or(&1)
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    pub fn weights(&self) -> &HostWeights {
        &self.host_weights
    }

    pub fn client(&self) -> &Arc<Client> {
        &self.client
    }

    /// Host<->device activation traffic so far (see [`TransferTotals`]).
    pub fn transfer_totals(&self) -> TransferTotals {
        self.transfers.totals()
    }

    /// Resolve (and memoize) the program for one grid entry. Table hits
    /// cost two `Vec` position scans over <= ~10 entries — no mutex, no
    /// string hashing.
    fn program(&self, kind: ArtifactKind, n: usize, batch: usize) -> Result<Program> {
        let idx = self.table.borrow().index(kind, n, batch);
        if let Some(i) = idx {
            if let Some(p) = self.table.borrow().slots[i].clone() {
                return Ok(p);
            }
        }
        let art = self.manifest.artifact(kind, n, batch)?;
        let exe = self.client.load_hlo(&art.name, &art.file)?;
        let prog = Program { exe, root: art.root };
        if let Some(i) = idx {
            self.table.borrow_mut().slots[i] = Some(prog.clone());
        }
        Ok(prog)
    }

    /// Eagerly compile the programs a serving run will need (avoids
    /// first-request compile latency in latency-sensitive benches) and
    /// fill the pre-resolved table the hot loop indexes into.
    pub fn warmup(&self, batches: &[usize]) -> Result<()> {
        for &b in batches {
            for n in self.config.all_token_counts() {
                self.program(ArtifactKind::BlockY, n, b)?;
            }
            for &n in &self.config.token_buckets {
                self.program(ArtifactKind::BlockKV, n, b)?;
            }
        }
        self.program(ArtifactKind::BlockReg, self.config.tokens, 1)?;
        Ok(())
    }

    /// Whether `(kind, n, batch)` programs chain device-to-device: their
    /// root is the bare activation array (manifest v4). Tuple-root grids
    /// (pre-v4 artifacts) make the step loop fall back to host stepping;
    /// resolution errors also answer `false` — the host path will surface
    /// the same error with context.
    pub fn device_chain_supported(&self, kind: ArtifactKind, n: usize, batch: usize) -> bool {
        self.program(kind, n, batch)
            .map(|p| p.root == ArtifactRoot::Array)
            .unwrap_or(false)
    }

    /// Upload a packed activation tensor (counted step-loop traffic).
    pub fn upload_activations(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.transfers.count_h2d(data.len());
        self.client.upload(data, dims)
    }

    /// Upload one block's staged K/V pair (counted both as ordinary H2D
    /// traffic and under the KV-specific byte counter — a warm device
    /// KV tier drives `kv_h2d_bytes` to zero in steady state).
    pub fn upload_kv_pair(
        &self,
        k: &[f32],
        v: &[f32],
        dims: &[usize],
    ) -> Result<(PjRtBuffer, PjRtBuffer)> {
        self.transfers.count_kv_h2d(k.len() + v.len());
        let kb = self.upload_activations(k, dims)?;
        let vb = self.upload_activations(v, dims)?;
        Ok((kb, vb))
    }

    /// Record a device-KV-tier hit (block served with no upload).
    pub fn note_kv_dev_hit(&self) {
        self.transfers.kv_dev_hits.set(self.transfers.kv_dev_hits.get() + 1);
    }

    /// Record a device-KV-tier miss (staged K/V had to be uploaded).
    pub fn note_kv_dev_miss(&self) {
        self.transfers.kv_dev_misses.set(self.transfers.kv_dev_misses.get() + 1);
    }

    /// Credit upload time hidden under the previous block's compute by
    /// the second copy stream.
    pub fn note_kv_prefetch_overlap(&self, d: std::time::Duration) {
        let c = &self.transfers.kv_prefetch_overlap_us;
        c.set(c.get() + d.as_micros() as u64);
    }

    /// Record a disk-tier degradation (read error / corruption forced a
    /// full template recompute — the bottom rung of the ladder).
    pub fn note_cache_degraded_disk(&self) {
        let c = &self.transfers.cache_degraded_disk;
        c.set(c.get() + 1);
    }

    /// Record a device-KV-tier degradation (upload/retention failure;
    /// blocks re-upload from host per step).
    pub fn note_cache_degraded_device(&self, n: u64) {
        let c = &self.transfers.cache_degraded_device;
        c.set(c.get() + n);
    }

    /// Record a loader degradation (staging job died; synchronous host
    /// gather served the block instead).
    pub fn note_cache_degraded_loader(&self) {
        let c = &self.transfers.cache_degraded_loader;
        c.set(c.get() + 1);
    }

    /// Root-aware readback of a block output into `out` (counted).
    fn read_block_output(&self, prog: &Program, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let v = match prog.root {
            ArtifactRoot::Array => buffer_to_vec(buf)?,
            ArtifactRoot::Tuple => {
                let mut parts = tuple_to_vecs(buf)?;
                anyhow::ensure!(parts.len() == 1, "block returns 1-tuple");
                parts.pop().unwrap()
            }
        };
        self.transfers.count_d2h(v.len());
        Ok(v)
    }

    /// Download the final buffer of a device-resident block chain
    /// (counted). The readback `Vec` is allocated inside the xla crate's
    /// literal conversion and *moved* into `out` — the scratch slot
    /// bounds live allocations to one per run, it cannot elide this one
    /// (see ROADMAP "Hot path").
    pub fn fetch_block_output(
        &self,
        kind: ArtifactKind,
        n: usize,
        batch: usize,
        buf: &PjRtBuffer,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let prog = self.program(kind, n, batch)?;
        *out = self.read_block_output(&prog, buf)?;
        Ok(())
    }

    /// Execute one cache-Y (or full, n == L) block.
    ///
    /// `x` is the packed `(batch, n, H)` compute-set input; returns the
    /// block output in the same layout. Host round trip per call — the
    /// reference path; the step loop uses [`ModelRuntime::run_block_y_dev`].
    pub fn run_block_y(
        &self,
        block_idx: usize,
        n: usize,
        batch: usize,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let h = self.config.hidden;
        anyhow::ensure!(x.len() == batch * n * h, "run_block_y input shape");
        let prog = self.program(ArtifactKind::BlockY, n, batch)?;
        let x_buf = self.upload_activations(x, &[batch, n, h])?;
        let out = self.execute_with_weights(&prog, &[&x_buf], block_idx)?;
        self.read_block_output(&prog, &out)
    }

    /// Device-resident cache-Y (or full) block: consumes the previous
    /// block's output buffer, returns this block's — no host copy.
    /// Requires an array-root artifact (`device_chain_supported`).
    pub fn run_block_y_dev(
        &self,
        block_idx: usize,
        n: usize,
        batch: usize,
        x: &PjRtBuffer,
    ) -> Result<PjRtBuffer> {
        let prog = self.program(ArtifactKind::BlockY, n, batch)?;
        anyhow::ensure!(
            prog.root == ArtifactRoot::Array,
            "run_block_y_dev requires array-root artifacts (manifest v4)"
        );
        self.execute_with_weights(&prog, &[x], block_idx)
    }

    /// Execute one cache-KV block: masked Q attends over computed K/V ++
    /// cached unmasked K/V (`k_cache`/`v_cache`: `(batch, L - n, H)`).
    pub fn run_block_kv(
        &self,
        block_idx: usize,
        n: usize,
        batch: usize,
        x: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
    ) -> Result<Vec<f32>> {
        let h = self.config.hidden;
        let l = self.config.tokens;
        anyhow::ensure!(x.len() == batch * n * h, "run_block_kv x shape");
        anyhow::ensure!(
            k_cache.len() == batch * (l - n) * h && v_cache.len() == k_cache.len(),
            "run_block_kv cache shape"
        );
        let prog = self.program(ArtifactKind::BlockKV, n, batch)?;
        let x_buf = self.upload_activations(x, &[batch, n, h])?;
        let k_buf = self.upload_activations(k_cache, &[batch, l - n, h])?;
        let v_buf = self.upload_activations(v_cache, &[batch, l - n, h])?;
        let out = self.execute_with_weights(&prog, &[&x_buf, &k_buf, &v_buf], block_idx)?;
        self.read_block_output(&prog, &out)
    }

    /// Device-resident cache-KV block: `x` chains from the previous
    /// block; `k_cache`/`v_cache` are pre-resident device buffers —
    /// either pinned in the device KV tier (warm: no upload at all) or
    /// uploaded once by the engine's prefetch stream on a miss.
    pub fn run_block_kv_dev(
        &self,
        block_idx: usize,
        n: usize,
        batch: usize,
        x: &PjRtBuffer,
        k_cache: &PjRtBuffer,
        v_cache: &PjRtBuffer,
    ) -> Result<PjRtBuffer> {
        let prog = self.program(ArtifactKind::BlockKV, n, batch)?;
        anyhow::ensure!(
            prog.root == ArtifactRoot::Array,
            "run_block_kv_dev requires array-root artifacts (manifest v4)"
        );
        self.execute_with_weights(&prog, &[x, k_cache, v_cache], block_idx)
    }

    /// Execute one registration block (batch 1, full sequence):
    /// returns (y, k, v), each `(L, H)` flattened.
    pub fn run_block_reg(&self, block_idx: usize, x: &[f32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let h = self.config.hidden;
        let l = self.config.tokens;
        anyhow::ensure!(x.len() == l * h, "run_block_reg input shape");
        let prog = self.program(ArtifactKind::BlockReg, l, 1)?;
        // registration is a one-off trace, not step traffic: uncounted
        let x_buf = self.upload(x, &[1, l, h])?;
        let out = self.execute_with_weights(&prog, &[&x_buf], block_idx)?;
        let mut parts = tuple_to_vecs(&out)?;
        anyhow::ensure!(parts.len() == 3, "block_reg returns (y, k, v)");
        let v = parts.pop().unwrap();
        let k = parts.pop().unwrap();
        let y = parts.pop().unwrap();
        Ok((y, k, v))
    }

    fn execute_with_weights(
        &self,
        prog: &Program,
        data_args: &[&PjRtBuffer],
        block_idx: usize,
    ) -> Result<PjRtBuffer> {
        let wbufs = &self.device_weights.blocks[block_idx];
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(data_args.len() + wbufs.len());
        args.extend(data_args.iter().copied());
        args.extend(wbufs.iter());
        let mut results = prog
            .exe
            .execute_b(&args)
            .context("PJRT execute")?;
        let mut replica = results
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .context("empty execution result")?;
        // results is Vec<Vec<buffer>>: [replica][output]; tuple packing
        // (or an array root) means a single output buffer.
        let _ = &mut replica;
        Ok(replica)
    }

    /// Upload helper for tests/benches (uncounted: not step traffic).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client.upload(data, dims)
    }

    /// Fetch helper for tests/benches (uncounted: not step traffic).
    pub fn fetch(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        buffer_to_vec(buf)
    }
}

/// Literal re-export for integration tests.
pub fn make_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    literal_f32(data, dims)
}
