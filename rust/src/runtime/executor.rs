//! Block executor: the bridge between the coordinator's step loop and the
//! AOT-compiled XLA programs.
//!
//! One `ModelRuntime` per (worker, model): it owns the PJRT client handle,
//! the device-resident weights, and the schedule/embedding tables, and
//! exposes typed `run_block_*` calls operating on host f32 slices. Data
//! (activations) travel host->device per call — they change every step —
//! while weights stay resident (see weights.rs).

use std::sync::Arc;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::client::{buffer_to_vec, literal_f32, tuple_to_vecs, Client};
use super::manifest::{ArtifactKind, Manifest, ModelManifest};
use super::weights::{DeviceWeights, HostWeights};
use crate::config::ModelConfig;
use crate::model::Schedule;

/// Executable handle + metadata for one grid entry.
struct Program {
    exe: Arc<xla::PjRtLoadedExecutable>,
}

/// Per-model runtime: compiled programs + weights + schedule.
pub struct ModelRuntime {
    client: Arc<Client>,
    manifest: ModelManifest,
    pub config: ModelConfig,
    batch_buckets: Vec<usize>,
    host_weights: HostWeights,
    device_weights: DeviceWeights,
    schedule: Schedule,
}

// SAFETY: ModelRuntime transitively holds `Rc`-based PJRT handles, so it
// is only sound to *move* a runtime (together with the sole Arc<Client>
// strong reference it was built from) onto another thread and use it
// exclusively there. The engine upholds this: each Worker constructs its
// own Client + ModelRuntime pair via `ModelRuntime::create`, moves them
// into the worker thread, and never shares them. Loader / pre-post
// threads operate on plain host data only.
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    /// Construct a private client + runtime pair (the only safe way to
    /// build a runtime that will move to a worker thread).
    pub fn create(artifact_dir: &str, model: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = Arc::new(Client::cpu()?);
        ModelRuntime::load(client, &manifest, model)
    }

    /// Load a model runtime from the manifest (lazy program compilation).
    pub fn load(client: Arc<Client>, manifest: &Manifest, model: &str) -> Result<ModelRuntime> {
        let man = manifest.model(model)?.clone();
        let config = man.config.clone();
        let host_weights = HostWeights::load(&man)?;
        let device_weights = DeviceWeights::upload(&client, &host_weights)?;
        let schedule = Schedule::new(host_weights.sigmas.clone());
        Ok(ModelRuntime {
            client,
            manifest: man,
            config,
            batch_buckets: manifest.batch_buckets.clone(),
            host_weights,
            device_weights,
            schedule,
        })
    }

    /// Smallest compiled batch bucket covering `b` members.
    pub fn batch_bucket_for(&self, b: usize) -> usize {
        for &bb in &self.batch_buckets {
            if bb >= b {
                return bb;
            }
        }
        *self.batch_buckets.last().unwrap_or(&1)
    }

    /// Largest compiled batch bucket (engine max-batch clamp).
    pub fn max_batch_bucket(&self) -> usize {
        *self.batch_buckets.last().unwrap_or(&1)
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    pub fn weights(&self) -> &HostWeights {
        &self.host_weights
    }

    pub fn client(&self) -> &Arc<Client> {
        &self.client
    }

    fn program(&self, kind: ArtifactKind, n: usize, batch: usize) -> Result<Program> {
        let art = self.manifest.artifact(kind, n, batch)?;
        let exe = self.client.load_hlo(&art.name, &art.file)?;
        Ok(Program { exe })
    }

    /// Eagerly compile the programs a serving run will need (avoids
    /// first-request compile latency in latency-sensitive benches).
    pub fn warmup(&self, batches: &[usize]) -> Result<()> {
        for &b in batches {
            for n in self.config.all_token_counts() {
                self.program(ArtifactKind::BlockY, n, b)?;
            }
            for &n in &self.config.token_buckets {
                self.program(ArtifactKind::BlockKV, n, b)?;
            }
        }
        self.program(ArtifactKind::BlockReg, self.config.tokens, 1)?;
        Ok(())
    }

    /// Execute one cache-Y (or full, n == L) block.
    ///
    /// `x` is the packed `(batch, n, H)` compute-set input; returns the
    /// block output in the same layout.
    pub fn run_block_y(
        &self,
        block_idx: usize,
        n: usize,
        batch: usize,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let h = self.config.hidden;
        anyhow::ensure!(x.len() == batch * n * h, "run_block_y input shape");
        let prog = self.program(ArtifactKind::BlockY, n, batch)?;
        let x_buf = self.client.upload(x, &[batch, n, h])?;
        let out = self.execute_with_weights(&prog, vec![x_buf], block_idx)?;
        let mut parts = tuple_to_vecs(&out)?;
        anyhow::ensure!(parts.len() == 1, "block_y returns 1-tuple");
        Ok(parts.pop().unwrap())
    }

    /// Execute one cache-KV block: masked Q attends over computed K/V ++
    /// cached unmasked K/V (`k_cache`/`v_cache`: `(batch, L - n, H)`).
    pub fn run_block_kv(
        &self,
        block_idx: usize,
        n: usize,
        batch: usize,
        x: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
    ) -> Result<Vec<f32>> {
        let h = self.config.hidden;
        let l = self.config.tokens;
        anyhow::ensure!(x.len() == batch * n * h, "run_block_kv x shape");
        anyhow::ensure!(
            k_cache.len() == batch * (l - n) * h && v_cache.len() == k_cache.len(),
            "run_block_kv cache shape"
        );
        let prog = self.program(ArtifactKind::BlockKV, n, batch)?;
        let x_buf = self.client.upload(x, &[batch, n, h])?;
        let k_buf = self.client.upload(k_cache, &[batch, l - n, h])?;
        let v_buf = self.client.upload(v_cache, &[batch, l - n, h])?;
        let out = self.execute_with_weights(&prog, vec![x_buf, k_buf, v_buf], block_idx)?;
        let mut parts = tuple_to_vecs(&out)?;
        anyhow::ensure!(parts.len() == 1, "block_kv returns 1-tuple");
        Ok(parts.pop().unwrap())
    }

    /// Execute one registration block (batch 1, full sequence):
    /// returns (y, k, v), each `(L, H)` flattened.
    pub fn run_block_reg(&self, block_idx: usize, x: &[f32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let h = self.config.hidden;
        let l = self.config.tokens;
        anyhow::ensure!(x.len() == l * h, "run_block_reg input shape");
        let prog = self.program(ArtifactKind::BlockReg, l, 1)?;
        let x_buf = self.client.upload(x, &[1, l, h])?;
        let out = self.execute_with_weights(&prog, vec![x_buf], block_idx)?;
        let mut parts = tuple_to_vecs(&out)?;
        anyhow::ensure!(parts.len() == 3, "block_reg returns (y, k, v)");
        let v = parts.pop().unwrap();
        let k = parts.pop().unwrap();
        let y = parts.pop().unwrap();
        Ok((y, k, v))
    }

    fn execute_with_weights(
        &self,
        prog: &Program,
        data_args: Vec<PjRtBuffer>,
        block_idx: usize,
    ) -> Result<PjRtBuffer> {
        let wbufs = &self.device_weights.blocks[block_idx];
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(data_args.len() + wbufs.len());
        args.extend(data_args.iter());
        args.extend(wbufs.iter());
        let mut results = prog
            .exe
            .execute_b(&args)
            .context("PJRT execute")?;
        let mut replica = results
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .context("empty execution result")?;
        // results is Vec<Vec<buffer>>: [replica][output]; tuple packing
        // means a single output buffer.
        let _ = &mut replica;
        Ok(replica)
    }

    /// Upload helper for tests/benches.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client.upload(data, dims)
    }

    /// Fetch helper for tests/benches.
    pub fn fetch(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        buffer_to_vec(buf)
    }
}

/// Literal re-export for integration tests.
pub fn make_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    literal_f32(data, dims)
}
