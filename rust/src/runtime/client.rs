//! PJRT client wrapper: lazy compile + executable cache.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** -> HloModuleProto
//! (the text parser reassigns instruction ids, sidestepping the 64-bit-id
//! incompatibility between jax >= 0.5 protos and xla_extension 0.5.1) ->
//! XlaComputation -> PJRT compile. Executables are cached by artifact
//! name; compilation happens on first use so startup stays fast even
//! though the grid holds ~40 programs per model.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Shared PJRT CPU client with an executable cache.
pub struct Client {
    client: PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<PjRtLoadedExecutable>>>,
}

// NOTE: no Send/Sync impls here on purpose. The xla crate's PjRtClient
// wraps an `Rc`, whose refcount updates are not atomic — a Client must
// stay on the thread that uses it. Each worker therefore owns a private
// Client + ModelRuntime (see runtime::executor for the Send invariant).

impl Client {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Client> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file, caching by `key`.
    pub fn load_hlo(
        &self,
        key: &str,
        path: &Path,
    ) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(key) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(key.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Upload a host f32 buffer to the device (for persistent weights).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .context("uploading buffer")
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {:?} vs len {}", dims, data.len());
    let lit = Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshaping literal")
}

/// Copy an output buffer back to host f32s.
pub fn buffer_to_vec(buf: &PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync().context("device->host copy")?;
    lit.to_vec::<f32>().context("literal to f32 vec")
}

/// Unpack a 1-tuple result literal (lowering uses return_tuple=True).
pub fn tuple1_to_vec(buf: &PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync().context("device->host copy")?;
    let inner = lit.to_tuple1().context("unwrapping 1-tuple")?;
    inner.to_vec::<f32>().context("tuple elem to f32 vec")
}

/// Unpack an N-tuple result literal into vectors.
pub fn tuple_to_vecs(buf: &PjRtBuffer) -> Result<Vec<Vec<f32>>> {
    let lit = buf.to_literal_sync().context("device->host copy")?;
    let parts = lit.to_tuple().context("unwrapping tuple")?;
    parts
        .into_iter()
        .map(|p| p.to_vec::<f32>().context("tuple elem to f32 vec"))
        .collect()
}
