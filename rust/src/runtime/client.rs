//! PJRT client wrapper: lazy compile + executable cache.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** -> HloModuleProto
//! (the text parser reassigns instruction ids, sidestepping the 64-bit-id
//! incompatibility between jax >= 0.5 protos and xla_extension 0.5.1) ->
//! XlaComputation -> PJRT compile. Executables are cached by artifact
//! name; compilation happens on first use so startup stays fast even
//! though the grid holds ~40 programs per model.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// One executable cache slot. The per-key mutex serializes compilation
/// of that artifact: a second caller that races the first blocks on the
/// slot (not the whole cache) and receives the already-compiled
/// executable instead of compiling again. A failed compile leaves the
/// slot empty so the next caller retries. (Today a `Client` is
/// thread-confined — see the Send/Sync NOTE below — so the race is
/// structural future-proofing: runtimes sharing one `Arc<Client>` must
/// stay compile-once even if a later refactor lets them run
/// concurrently.)
type Slot = Arc<Mutex<Option<Arc<PjRtLoadedExecutable>>>>;

/// Shared PJRT CPU client with an executable cache.
pub struct Client {
    client: PjRtClient,
    cache: Mutex<HashMap<String, Slot>>,
}

// NOTE: no Send/Sync impls here on purpose. The xla crate's PjRtClient
// wraps an `Rc`, whose refcount updates are not atomic — a Client must
// stay on the thread that uses it. Each worker therefore owns a private
// Client + ModelRuntime (see runtime::executor for the Send invariant).

impl Client {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Client> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file, caching by `key`. Compile-once:
    /// concurrent callers of the same key serialize on a per-key slot
    /// (the old check-then-insert let both compile and one win the
    /// insert), and distinct keys still compile independently.
    pub fn load_hlo(&self, key: &str, path: &Path) -> Result<Arc<PjRtLoadedExecutable>> {
        let slot: Slot = Arc::clone(
            self.cache
                .lock()
                .unwrap()
                .entry(key.to_string())
                .or_default(),
        );
        let mut guard = slot.lock().unwrap();
        if let Some(exe) = guard.as_ref() {
            return Ok(Arc::clone(exe));
        }
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let exe = Arc::new(exe);
        *guard = Some(Arc::clone(&exe));
        Ok(exe)
    }

    /// Number of compiled executables currently cached (slots created by
    /// a failed compile stay empty and are not counted).
    pub fn compiled_count(&self) -> usize {
        self.cache
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.lock().unwrap().is_some())
            .count()
    }

    /// Upload a host f32 buffer to the device (for persistent weights).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .context("uploading buffer")
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {:?} vs len {}", dims, data.len());
    let lit = Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshaping literal")
}

/// Copy an output buffer back to host f32s.
pub fn buffer_to_vec(buf: &PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync().context("device->host copy")?;
    lit.to_vec::<f32>().context("literal to f32 vec")
}

/// Unpack a 1-tuple result literal (lowering uses return_tuple=True).
pub fn tuple1_to_vec(buf: &PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync().context("device->host copy")?;
    let inner = lit.to_tuple1().context("unwrapping 1-tuple")?;
    inner.to_vec::<f32>().context("tuple elem to f32 vec")
}

/// Unpack an N-tuple result literal into vectors.
pub fn tuple_to_vecs(buf: &PjRtBuffer) -> Result<Vec<Vec<f32>>> {
    let lit = buf.to_literal_sync().context("device->host copy")?;
    let parts = lit.to_tuple().context("unwrapping tuple")?;
    parts
        .into_iter()
        .map(|p| p.to_vec::<f32>().context("tuple elem to f32 vec"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failed_compile_leaves_slot_retryable() {
        // A bad artifact path must error out without poisoning the
        // per-key slot or counting as a cached executable.
        let Ok(client) = Client::cpu() else { return };
        let bad = Path::new("/nonexistent/artifact.hlo.txt");
        assert!(client.load_hlo("k", bad).is_err());
        assert_eq!(client.compiled_count(), 0);
        // retry goes through the same slot (no deadlock, still an error)
        assert!(client.load_hlo("k", bad).is_err());
        assert_eq!(client.compiled_count(), 0);
    }
}
