//! Weights loading: artifacts/weights_<model>.bin -> host tensors +
//! persistent device buffers.
//!
//! The flat little-endian f32 stream is indexed by the manifest's layout
//! entries; per-block weights are uploaded to the PJRT device **once** at
//! startup and passed to every block execution as `PjRtBuffer`s, so the
//! hot path never re-copies weights host->device.

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use super::client::Client;
use super::manifest::ModelManifest;
use crate::util::tensor::Tensor;

/// Host-side copy of everything in the weights file.
pub struct HostWeights {
    /// Per block: tensors in manifest block_weight_order.
    pub blocks: Vec<Vec<Tensor>>,
    /// (steps, H) timestep-embedding table.
    pub temb: Tensor,
    /// (steps + 1,) sigma schedule.
    pub sigmas: Vec<f32>,
    /// (H, C) VAE-analogue decoder.
    pub decoder: Tensor,
    /// (C, H) VAE-analogue encoder.
    pub encoder: Tensor,
}

impl HostWeights {
    /// Read and slice the weights file per the manifest layout.
    pub fn load(man: &ModelManifest) -> Result<HostWeights> {
        let bytes = std::fs::read(&man.weights_file)
            .with_context(|| format!("reading {:?}", man.weights_file))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weights file not f32-aligned");
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }

        let slice_of = |name: &str| -> Result<Tensor> {
            let e = man.weight(name)?;
            anyhow::ensure!(
                e.offset + e.len <= data.len(),
                "weight {name} out of bounds"
            );
            Tensor::from_vec(&e.shape, data[e.offset..e.offset + e.len].to_vec())
        };

        let mut blocks = Vec::with_capacity(man.config.blocks);
        for b in 0..man.config.blocks {
            let mut ws = Vec::with_capacity(man.block_weight_order.len());
            for wname in &man.block_weight_order {
                ws.push(slice_of(&format!("block{b}.{wname}"))?);
            }
            blocks.push(ws);
        }
        Ok(HostWeights {
            blocks,
            temb: slice_of("temb")?,
            sigmas: slice_of("sigmas")?.into_vec(),
            decoder: slice_of("decoder")?,
            encoder: slice_of("encoder")?,
        })
    }

    /// Timestep-embedding row for denoise step `t`.
    pub fn temb_row(&self, t: usize) -> &[f32] {
        self.temb.row(t)
    }
}

/// Device-resident per-block weight buffers.
pub struct DeviceWeights {
    /// blocks[b] = the 12 weight buffers in block_weight_order.
    pub blocks: Vec<Vec<PjRtBuffer>>,
}

impl DeviceWeights {
    /// Upload every block's weights once.
    pub fn upload(client: &Client, host: &HostWeights) -> Result<DeviceWeights> {
        let mut blocks = Vec::with_capacity(host.blocks.len());
        for ws in &host.blocks {
            let mut bufs = Vec::with_capacity(ws.len());
            for t in ws {
                bufs.push(client.upload(t.data(), t.shape())?);
            }
            blocks.push(bufs);
        }
        Ok(DeviceWeights { blocks })
    }
}
