//! artifacts/manifest.json loading — the contract between the python
//! compile path and the rust coordinator.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Kind of a compiled block program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Cache-Y / full block: x -> y over the compute set (n == L is the
    /// standard full block).
    BlockY,
    /// Cache-KV block: (x, k_cache, v_cache) -> y.
    BlockKV,
    /// Registration block: x -> (y, k, v) at batch 1, full sequence.
    BlockReg,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "block_y" => ArtifactKind::BlockY,
            "block_kv" => ArtifactKind::BlockKV,
            "block_reg" => ArtifactKind::BlockReg,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// Shape of a compiled program's result (manifest v4 `root` field).
///
/// `Array` programs return the bare activation tensor, so their output
/// buffer feeds the next block's execute directly — the device-resident
/// step loop requires it. `Tuple` programs (manifest <= v3 grids and the
/// 3-output registration block) wrap results in a tuple literal that must
/// round-trip through the host to unwrap; the step loop falls back to
/// host stepping for them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactRoot {
    Tuple,
    Array,
}

impl ArtifactRoot {
    fn parse(s: Option<&str>) -> ArtifactRoot {
        match s {
            Some("array") => ArtifactRoot::Array,
            _ => ArtifactRoot::Tuple,
        }
    }
}

/// One compiled HLO program in the grid.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub n: usize,
    pub batch: usize,
    pub root: ArtifactRoot,
}

/// A named tensor inside the weights file.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Everything the runtime needs to know about one model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub config: ModelConfig,
    pub weights_file: PathBuf,
    pub weights: Vec<WeightEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub block_weight_order: Vec<String>,
}

/// The parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    pub batch_buckets: Vec<usize>,
    pub image_channels: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let block_weight_order: Vec<String> = v
            .at("block_weight_order")
            .as_arr()
            .context("block_weight_order")?
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect();

        let mut models = BTreeMap::new();
        for (name, m) in v.at("models").as_obj().context("models")?.iter() {
            let config = ModelConfig {
                name: name.clone(),
                latent_hw: m.at("latent_hw").as_usize().context("latent_hw")?,
                tokens: m.at("tokens").as_usize().context("tokens")?,
                hidden: m.at("hidden").as_usize().context("hidden")?,
                heads: m.at("heads").as_usize().context("heads")?,
                blocks: m.at("blocks").as_usize().context("blocks")?,
                steps: m.at("steps").as_usize().context("steps")?,
                token_buckets: m.at("token_buckets").usize_list(),
                paper_analogue: m
                    .at("paper_analogue")
                    .as_str()
                    .unwrap_or_default()
                    .to_string(),
            };
            let weights = m
                .at("weights")
                .as_arr()
                .context("weights")?
                .iter()
                .map(|w| {
                    Ok(WeightEntry {
                        name: w.at("name").as_str().context("w.name")?.to_string(),
                        shape: w.at("shape").usize_list(),
                        offset: w.at("offset").as_usize().context("w.offset")?,
                        len: w.at("len").as_usize().context("w.len")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let artifacts = m
                .at("artifacts")
                .as_arr()
                .context("artifacts")?
                .iter()
                .map(|a| {
                    Ok(ArtifactEntry {
                        name: a.at("name").as_str().context("a.name")?.to_string(),
                        file: dir.join(a.at("file").as_str().context("a.file")?),
                        kind: ArtifactKind::parse(a.at("kind").as_str().context("a.kind")?)?,
                        n: a.at("n").as_usize().context("a.n")?,
                        batch: a.at("batch").as_usize().context("a.batch")?,
                        root: ArtifactRoot::parse(a.at("root").as_str()),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    config,
                    weights_file: dir.join(
                        m.at("weights_file").as_str().context("weights_file")?,
                    ),
                    weights,
                    artifacts,
                    block_weight_order: block_weight_order.clone(),
                },
            );
        }
        Ok(Manifest {
            dir,
            models,
            batch_buckets: v.at("batch_buckets").usize_list(),
            image_channels: v.at("image_channels").as_usize().unwrap_or(4),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }

    /// Smallest batch bucket covering `b`.
    pub fn batch_bucket_for(&self, b: usize) -> usize {
        for &bb in &self.batch_buckets {
            if bb >= b {
                return bb;
            }
        }
        *self.batch_buckets.last().unwrap_or(&1)
    }
}

impl ModelManifest {
    /// Find the artifact for (kind, n, batch).
    pub fn artifact(&self, kind: ArtifactKind, n: usize, batch: usize) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.n == n && a.batch == batch)
            .with_context(|| {
                format!(
                    "no artifact kind={kind:?} n={n} batch={batch} for {}",
                    self.config.name
                )
            })
    }

    pub fn weight(&self, name: &str) -> Result<&WeightEntry> {
        self.weights
            .iter()
            .find(|w| w.name == name)
            .with_context(|| format!("weight {name:?} missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unit-level parse check against a synthetic manifest (integration
    /// tests in rust/tests/ cover the real artifacts/ directory).
    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("ig-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
          "version": 3, "image_channels": 4, "batch_buckets": [1, 2, 4, 8],
          "block_weight_order": ["ln1_g", "wq"],
          "models": {"tiny": {
            "latent_hw": 4, "tokens": 16, "hidden": 8, "heads": 2,
            "blocks": 2, "steps": 3, "token_buckets": [2, 4, 8],
            "paper_analogue": "test", "weights_file": "w.bin",
            "weights": [{"name": "block0.wq", "shape": [8, 8], "offset": 0, "len": 64}],
            "artifacts": [{"name": "a", "file": "a.hlo.txt",
                           "kind": "block_y", "n": 4, "batch": 2},
                          {"name": "b", "file": "b.hlo.txt",
                           "kind": "block_y", "n": 8, "batch": 2,
                           "root": "array"}]
          }}}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.batch_bucket_for(3), 4);
        assert_eq!(man.batch_bucket_for(9), 8); // saturates at max bucket
        let m = man.model("tiny").unwrap();
        assert_eq!(m.config.tokens, 16);
        assert!(m.artifact(ArtifactKind::BlockY, 4, 2).is_ok());
        // v3 manifests carry no `root`: default to the tuple convention;
        // v4 entries declare array roots explicitly
        assert_eq!(
            m.artifact(ArtifactKind::BlockY, 4, 2).unwrap().root,
            ArtifactRoot::Tuple
        );
        assert_eq!(
            m.artifact(ArtifactKind::BlockY, 8, 2).unwrap().root,
            ArtifactRoot::Array
        );
        assert!(m.artifact(ArtifactKind::BlockKV, 4, 2).is_err());
        assert_eq!(m.weight("block0.wq").unwrap().len, 64);
        std::fs::remove_dir_all(&dir).ok();
    }
}
