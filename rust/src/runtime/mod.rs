//! Runtime layer: PJRT client, manifest/artifact registry, weights, and
//! the block executor (start point: /opt/xla-example/load_hlo).

pub mod client;
pub mod executor;
pub mod manifest;
pub mod weights;

pub use client::Client;
pub use executor::{ModelRuntime, TransferTotals};
pub use manifest::{ArtifactKind, ArtifactRoot, Manifest, ModelManifest};
