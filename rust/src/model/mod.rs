//! Model-side host types: masks, masked-first permutation, latents,
//! packing, and the denoising schedule.

pub mod latent;
pub mod mask;
pub mod schedule;

pub use latent::{Latent, PackBuffer};
pub use mask::{MaskSpec, Permutation};
pub use schedule::Schedule;
