//! Per-request latent state and batch packing.
//!
//! Each in-flight request owns a full `(L, H)` latent in canonical token
//! order. The step loop packs the *compute rows* (the masked-first prefix
//! of each member's permutation) of up to B requests into one dense
//! `(B, n, H)` buffer for the block executables, and scatters results
//! back. Buffers are caller-provided and reused across steps — the pack /
//! unpack path is allocation-free (§Perf target).

use crate::model::mask::Permutation;
use crate::util::rng::Pcg;

/// Full-latent state of one request (canonical token order).
#[derive(Debug, Clone)]
pub struct Latent {
    data: Vec<f32>,
    tokens: usize,
    hidden: usize,
}

impl Latent {
    pub fn zeros(tokens: usize, hidden: usize) -> Latent {
        Latent { data: vec![0.0; tokens * hidden], tokens, hidden }
    }

    /// Seeded standard-normal latent (template trajectory starts).
    pub fn noise(tokens: usize, hidden: usize, seed: u64, scale: f32) -> Latent {
        let mut l = Latent::zeros(tokens, hidden);
        Pcg::new(seed).fill_normal_f32(&mut l.data, scale);
        l
    }

    pub fn tokens(&self) -> usize {
        self.tokens
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn row(&self, t: usize) -> &[f32] {
        &self.data[t * self.hidden..(t + 1) * self.hidden]
    }

    /// Gather token rows by id into `out` (ids.len() x H).
    pub fn gather_into(&self, ids: &[usize], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.hidden);
        for (i, &id) in ids.iter().enumerate() {
            out[i * self.hidden..(i + 1) * self.hidden]
                .copy_from_slice(self.row(id));
        }
    }

    /// Scatter rows back by id.
    pub fn scatter_from(&mut self, ids: &[usize], src: &[f32]) {
        debug_assert_eq!(src.len(), ids.len() * self.hidden);
        let h = self.hidden;
        for (i, &id) in ids.iter().enumerate() {
            self.data[id * h..(id + 1) * h]
                .copy_from_slice(&src[i * h..(i + 1) * h]);
        }
    }
}

/// Reusable packing buffer for a `(B, n, H)` compute batch.
#[derive(Debug, Default)]
pub struct PackBuffer {
    pub data: Vec<f32>,
}

impl PackBuffer {
    /// Pack the bucket-`n` compute rows of `members` into `(B, n, H)`;
    /// `conditioning(i, row_buf)` lets the caller add the per-member
    /// timestep embedding + prompt conditioning in the same pass (one
    /// traversal, no extra buffer).
    pub fn pack(
        &mut self,
        members: &[(&Latent, &Permutation)],
        n: usize,
        mut conditioning: impl FnMut(usize, &mut [f32]),
    ) {
        let b = members.len();
        let h = members.first().map(|(l, _)| l.hidden()).unwrap_or(0);
        self.data.resize(b * n * h, 0.0);
        for (i, (latent, perm)) in members.iter().enumerate() {
            let dst = &mut self.data[i * n * h..(i + 1) * n * h];
            latent.gather_into(perm.compute_ids(n), dst);
            conditioning(i, dst);
        }
    }

    /// Member `i`'s rows within the packed buffer.
    pub fn member(&self, i: usize, n: usize, h: usize) -> &[f32] {
        &self.data[i * n * h..(i + 1) * n * h]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mask::MaskSpec;

    #[test]
    fn gather_scatter_round_trip() {
        let mut l = Latent::noise(8, 4, 42, 1.0);
        let ids = [3usize, 1, 7];
        let mut buf = vec![0.0; ids.len() * 4];
        l.gather_into(&ids, &mut buf);
        let before = l.data().to_vec();
        l.scatter_from(&ids, &buf);
        assert_eq!(l.data(), &before[..]);
    }

    #[test]
    fn noise_is_seed_deterministic() {
        let a = Latent::noise(16, 8, 7, 0.5);
        let b = Latent::noise(16, 8, 7, 0.5);
        assert_eq!(a.data(), b.data());
        let c = Latent::noise(16, 8, 8, 0.5);
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn pack_applies_conditioning_per_member() {
        let mut rng = Pcg::new(0);
        let m1 = MaskSpec::synth(4, 0.25, &mut rng);
        let m2 = MaskSpec::synth(4, 0.25, &mut rng);
        let p1 = Permutation::masked_first(&m1);
        let p2 = Permutation::masked_first(&m2);
        let l1 = Latent::noise(16, 2, 1, 1.0);
        let l2 = Latent::noise(16, 2, 2, 1.0);
        let n = 4;
        let mut pb = PackBuffer::default();
        pb.pack(&[(&l1, &p1), (&l2, &p2)], n, |i, rows| {
            for v in rows.iter_mut() {
                *v += (i + 1) as f32 * 100.0;
            }
        });
        // member 0 rows got +100, member 1 rows +200
        let r0 = pb.member(0, n, 2);
        let want0 = l1.row(p1.compute_ids(n)[0])[0] + 100.0;
        assert!((r0[0] - want0).abs() < 1e-6);
        let r1 = pb.member(1, n, 2);
        let want1 = l2.row(p2.compute_ids(n)[0])[0] + 200.0;
        assert!((r1[0] - want1).abs() < 1e-6);
    }
}
