//! Masks and the masked-first permutation (paper §2.1/§3.1).
//!
//! A mask selects the latent tokens to be edited. The coordinator permutes
//! each request's tokens *masked-first* so the L1 kernel sees sparsity as
//! a dense leading-dimension crop (DESIGN.md §Hardware-Adaptation), and
//! pads the compute set up to the shape bucket with real unmasked tokens
//! (computed redundantly instead of read from cache — no validity masks
//! anywhere in the kernels).

use crate::util::rng::Pcg;

/// A mask over the latent token grid.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskSpec {
    /// Token ids (canonical order) inside the mask, sorted.
    masked: Vec<usize>,
    /// Total token count L.
    tokens: usize,
}

impl MaskSpec {
    pub fn new(mut masked: Vec<usize>, tokens: usize) -> MaskSpec {
        masked.sort_unstable();
        masked.dedup();
        assert!(masked.last().map(|&m| m < tokens).unwrap_or(true));
        assert!(!masked.is_empty(), "empty mask");
        MaskSpec { masked, tokens }
    }

    /// Synthesize a contiguous-blob mask of roughly `ratio * L` tokens on
    /// the `hw x hw` grid (rectangular region grown from a random anchor,
    /// mimicking production edit regions: try-on garments, faces, hands).
    pub fn synth(hw: usize, ratio: f64, rng: &mut Pcg) -> MaskSpec {
        let tokens = hw * hw;
        let want = ((ratio * tokens as f64).round() as usize).clamp(1, tokens);
        // rectangle with aspect jitter
        let aspect = rng.range_f64(0.5, 2.0);
        let mut h = ((want as f64 * aspect).sqrt().round() as usize).clamp(1, hw);
        let mut w = want.div_ceil(h).clamp(1, hw);
        while h * w < want && (h < hw || w < hw) {
            if h < hw {
                h += 1;
            } else {
                w += 1;
            }
        }
        let r0 = rng.below(hw - h + 1);
        let c0 = rng.below(hw - w + 1);
        let mut ids = Vec::with_capacity(want);
        'outer: for r in r0..r0 + h {
            for c in c0..c0 + w {
                ids.push(r * hw + c);
                if ids.len() == want {
                    break 'outer;
                }
            }
        }
        MaskSpec::new(ids, tokens)
    }

    pub fn masked_ids(&self) -> &[usize] {
        &self.masked
    }

    pub fn masked_count(&self) -> usize {
        self.masked.len()
    }

    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Mask ratio m (paper Table 1).
    pub fn ratio(&self) -> f64 {
        self.masked.len() as f64 / self.tokens as f64
    }

    pub fn is_masked(&self, id: usize) -> bool {
        self.masked.binary_search(&id).is_ok()
    }
}

/// The masked-first token permutation of one request.
///
/// `order[0..k]` are the masked ids, `order[k..]` the unmasked ids in
/// ascending canonical order. The *compute set* for a bucket `n >= k` is
/// `order[0..n]` — a prefix, so growing the bucket only appends filler
/// (the prefix property the continuous batcher relies on: a request can
/// join a batch with any bucket `>=` its own without re-permutation).
#[derive(Debug, Clone)]
pub struct Permutation {
    order: Vec<usize>,
    k: usize,
}

impl Permutation {
    pub fn masked_first(mask: &MaskSpec) -> Permutation {
        let l = mask.tokens();
        let mut order = Vec::with_capacity(l);
        order.extend_from_slice(mask.masked_ids());
        order.extend((0..l).filter(|&t| !mask.is_masked(t)));
        debug_assert_eq!(order.len(), l);
        Permutation { order, k: mask.masked_count() }
    }

    /// Token ids of the compute set for bucket `n` (prefix of the order).
    pub fn compute_ids(&self, n: usize) -> &[usize] {
        &self.order[..n]
    }

    /// Token ids replenished from cache for bucket `n` (the suffix).
    pub fn cached_ids(&self, n: usize) -> &[usize] {
        &self.order[n..]
    }

    /// Number of genuinely masked tokens k.
    pub fn masked_count(&self) -> usize {
        self.k
    }

    pub fn tokens(&self) -> usize {
        self.order.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn synth_hits_target_ratio() {
        let mut rng = Pcg::new(1);
        for &ratio in &[0.02, 0.1, 0.35, 0.9] {
            let m = MaskSpec::synth(16, ratio, &mut rng);
            let got = m.ratio();
            assert!(
                (got - ratio).abs() < 0.08,
                "ratio {ratio} got {got} ({} ids)",
                m.masked_count()
            );
        }
    }

    #[test]
    fn permutation_prefix_property() {
        let mut rng = Pcg::new(2);
        let m = MaskSpec::synth(8, 0.2, &mut rng);
        let p = Permutation::masked_first(&m);
        let k = p.masked_count();
        // masked ids form exactly the first k entries
        for &id in p.compute_ids(k) {
            assert!(m.is_masked(id));
        }
        // filler beyond k is unmasked
        for &id in &p.compute_ids(k + 5)[k..] {
            assert!(!m.is_masked(id));
        }
    }

    #[test]
    fn permutation_is_bijection_property() {
        prop_check("masked-first order is a permutation", 100, |rng| {
            let hw = 4 + rng.below(13); // 4..16
            let ratio = rng.range_f64(0.01, 0.99);
            let m = MaskSpec::synth(hw, ratio, rng);
            let p = Permutation::masked_first(&m);
            let l = m.tokens();
            let mut seen = vec![false; l];
            for &id in p.compute_ids(l) {
                prop_assert!(id < l, "id {id} out of range {l}");
                prop_assert!(!seen[id], "duplicate id {id}");
                seen[id] = true;
            }
            prop_assert!(seen.iter().all(|&s| s), "missing ids");
            // cached_ids ++ compute_ids partition the tokens at every bucket
            for n in [p.masked_count(), l / 2, l] {
                if n >= p.masked_count() && n <= l {
                    prop_assert!(
                        p.compute_ids(n).len() + p.cached_ids(n).len() == l,
                        "partition broken at n={n}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mask_dedups_and_sorts() {
        let m = MaskSpec::new(vec![5, 1, 5, 3], 8);
        assert_eq!(m.masked_ids(), &[1, 3, 5]);
        assert!(m.is_masked(3));
        assert!(!m.is_masked(2));
    }
}
