//! Denoising schedule + host-side step update (paper §2.1).
//!
//! The sigma schedule and timestep-embedding table are produced by the
//! python compile path (single source of truth) and shipped in the weights
//! file; this module applies the per-step latent update
//! `x_{t+1} = x_t - (sigma_t - sigma_{t+1}) * eps` on the host. The model
//! predicts eps as its final hidden state (DESIGN.md simplification).

/// Noise schedule: decreasing sigmas, `steps + 1` entries ending at 0.
#[derive(Debug, Clone)]
pub struct Schedule {
    sigmas: Vec<f32>,
}

impl Schedule {
    pub fn new(sigmas: Vec<f32>) -> Schedule {
        assert!(sigmas.len() >= 2);
        assert!(sigmas.windows(2).all(|w| w[0] > w[1]), "sigmas must decrease");
        assert_eq!(*sigmas.last().unwrap(), 0.0);
        Schedule { sigmas }
    }

    pub fn steps(&self) -> usize {
        self.sigmas.len() - 1
    }

    pub fn sigma(&self, step: usize) -> f32 {
        self.sigmas[step]
    }

    /// Step size `sigma_t - sigma_{t+1}` for denoise step `t`.
    pub fn delta(&self, step: usize) -> f32 {
        self.sigmas[step] - self.sigmas[step + 1]
    }

    /// Apply the update to selected rows of a (L, H) latent:
    /// `x[id] -= delta(step) * eps[row]` where `eps` holds one row per id.
    pub fn update_rows(
        &self,
        step: usize,
        latent: &mut [f32],
        hidden: usize,
        ids: &[usize],
        eps: &[f32],
    ) {
        debug_assert_eq!(eps.len(), ids.len() * hidden);
        let d = self.delta(step);
        for (row, &id) in ids.iter().enumerate() {
            let x = &mut latent[id * hidden..(id + 1) * hidden];
            let e = &eps[row * hidden..(row + 1) * hidden];
            for (xv, ev) in x.iter_mut().zip(e) {
                *xv -= d * ev;
            }
        }
    }

    /// [`Schedule::update_rows`] with eps rows gathered in place: the eps
    /// row for token `id` is read at `eps_full[id * hidden ..]` instead
    /// of from a pre-gathered staging buffer. The step loop's latent
    /// update uses this to skip the per-member eps gather allocation.
    pub fn update_rows_gathered(
        &self,
        step: usize,
        latent: &mut [f32],
        hidden: usize,
        ids: &[usize],
        eps_full: &[f32],
    ) {
        let d = self.delta(step);
        for &id in ids {
            let x = &mut latent[id * hidden..(id + 1) * hidden];
            let e = &eps_full[id * hidden..(id + 1) * hidden];
            for (xv, ev) in x.iter_mut().zip(e) {
                *xv -= d * ev;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Schedule {
        Schedule::new(vec![1.0, 0.6, 0.3, 0.0])
    }

    #[test]
    fn deltas_sum_to_initial_sigma() {
        let s = sched();
        let total: f32 = (0..s.steps()).map(|t| s.delta(t)).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn update_rows_touches_only_ids() {
        let s = sched();
        let h = 2;
        let mut latent = vec![1.0f32; 4 * h];
        let eps = vec![1.0f32; 2 * h];
        s.update_rows(0, &mut latent, h, &[1, 3], &eps);
        let d = s.delta(0);
        assert_eq!(latent[0], 1.0); // row 0 untouched
        assert!((latent[2] - (1.0 - d)).abs() < 1e-6); // row 1 updated
        assert_eq!(latent[4], 1.0); // row 2 untouched
        assert!((latent[6] - (1.0 - d)).abs() < 1e-6); // row 3 updated
    }

    #[test]
    #[should_panic(expected = "decrease")]
    fn rejects_non_monotone() {
        Schedule::new(vec![1.0, 1.2, 0.0]);
    }

    #[test]
    fn gathered_update_matches_staged_update() {
        let s = sched();
        let h = 2;
        let l = 4;
        let eps_full: Vec<f32> = (0..l * h).map(|i| i as f32 * 0.25).collect();
        let ids = [3usize, 1];
        // reference: gather eps rows into a staging buffer first
        let mut staged = vec![0f32; ids.len() * h];
        for (r, &id) in ids.iter().enumerate() {
            staged[r * h..(r + 1) * h].copy_from_slice(&eps_full[id * h..(id + 1) * h]);
        }
        let mut a = vec![1.0f32; l * h];
        let mut b = a.clone();
        s.update_rows(1, &mut a, h, &ids, &staged);
        s.update_rows_gathered(1, &mut b, h, &ids, &eps_full);
        assert_eq!(a, b);
    }
}
