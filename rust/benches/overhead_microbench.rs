//! §6.6 — system overheads (paper: scheduling 0.6 ms, per-step batching
//! 1.2 ms, latent serialization 1.1 ms, IPC 1.3 ms — all negligible vs
//! seconds-scale requests). We measure our analogues directly.

#[path = "common.rs"]
mod common;

use instgenie::cache::LatencyModel;
use instgenie::config::CacheMode;
use instgenie::model::{Latent, MaskSpec, PackBuffer, Permutation};
use instgenie::qos::Priority;
use instgenie::runtime::Manifest;
use instgenie::scheduler::{MaskAware, Outstanding, RouteCtx, Scheduler};
use instgenie::util::bench::{fmt_secs, time_it, Table};
use instgenie::util::rng::Pcg;

fn main() {
    let manifest = Manifest::load("artifacts").expect("artifacts");
    let cfg = manifest.model("fluxm").unwrap().config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", "fluxm");
    let mut table = Table::new(
        "§6.6 system overheads (fluxm shapes)",
        &["operation", "mean", "paper_analogue"],
    );

    // 1. scheduling decision (Algo 2 over 8 workers x 8 outstanding)
    let mut sched = MaskAware::new(cfg.clone(), lat, CacheMode::CacheY, 8);
    let mut rng = Pcg::new(1);
    let book: Vec<Vec<Outstanding>> = (0..8)
        .map(|_| {
            (0..8)
                .map(|i| Outstanding {
                    id: i,
                    masked_tokens: 1 + rng.below(cfg.tokens),
                    remaining_steps: cfg.steps,
                    priority: Priority::Standard,
                })
                .collect()
        })
        .collect();
    let req = Outstanding {
        id: 99,
        masked_tokens: 32,
        remaining_steps: cfg.steps,
        priority: Priority::Standard,
    };
    let ctx = RouteCtx::default();
    let s = time_it(10, common::scaled(200), || {
        std::hint::black_box(sched.pick(&req, &book, &ctx));
    });
    table.rowf(&[&"scheduler decision (Algo 2)", &fmt_secs(s.mean), &"0.6 ms"]);

    // 2. per-step batch packing (8 members, bucket L/4)
    let n = cfg.token_buckets[2];
    let mut rng = Pcg::new(2);
    let members: Vec<(Latent, Permutation)> = (0..8)
        .map(|i| {
            let mask = MaskSpec::synth(cfg.latent_hw, 0.15, &mut rng);
            (
                Latent::noise(cfg.tokens, cfg.hidden, i, 1.0),
                Permutation::masked_first(&mask),
            )
        })
        .collect();
    let mut pb = PackBuffer::default();
    let s = time_it(10, common::scaled(500), || {
        let refs: Vec<(&Latent, &Permutation)> =
            members.iter().map(|(l, p)| (l, p)).collect();
        pb.pack(&refs, n, |_, _| {});
        std::hint::black_box(&pb.data);
    });
    table.rowf(&[&"batch packing (8 x L/4 tokens)", &fmt_secs(s.mean), &"1.2 ms/step"]);

    // 3. latent serialization (the post-process handoff)
    let latent = Latent::noise(cfg.tokens, cfg.hidden, 3, 1.0);
    let s = time_it(10, common::scaled(500), || {
        let mut buf = Vec::with_capacity(latent.data().len() * 4);
        for v in latent.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        std::hint::black_box(buf);
    });
    table.rowf(&[&"latent serialization", &fmt_secs(s.mean), &"1.1 ms"]);

    // 4. pipeline DP itself (Algo 1, fluxm's 8 blocks)
    let lat2 = LatencyModel::load_or_nominal("artifacts", "fluxm");
    let costs = lat2.step_costs(&cfg, n, 8, CacheMode::CacheY);
    let s = time_it(10, common::scaled(2000), || {
        std::hint::black_box(instgenie::cache::pipeline::plan(&costs));
    });
    table.rowf(&[&"pipeline DP (Algo 1)", &fmt_secs(s.mean), &"negligible"]);

    // 5. memoized plan lookup — the per-step cost after the plan cache
    // (the DP now runs once per (n, b, mode, warm-mask) shape, not every
    // step)
    let mut plans = instgenie::cache::pipeline::PlanCache::new();
    let _ = plans.plan_for(n, 8, 0, 0, || costs.clone());
    let s = time_it(10, common::scaled(2000), || {
        std::hint::black_box(plans.plan_for(n, 8, 0, 0, || costs.clone()));
    });
    table.rowf(&[&"plan cache hit (Algo 1 memoized)", &fmt_secs(s.mean), &"negligible"]);

    // 6./7. per-step coordinator overhead: measured solo step latency
    // minus the pipeline's ideal latency — host-round-trip reference vs
    // the device-resident chain (the BENCH_overhead.json trajectory; see
    // examples/overhead_bench.rs for the full record).
    for (label, device) in [
        ("step overhead (host reference)", false),
        ("step overhead (device loop)", true),
    ] {
        match common::solo_step_overhead(device) {
            Some(overhead) => {
                table.rowf(&[&label, &fmt_secs(overhead), &"~1 ms/step budget"])
            }
            None => table.rowf(&[&label, &"skipped (no artifacts)", &"~1 ms/step budget"]),
        }
    }

    table.print();
    table.save_csv("overhead_microbench").ok();
}
