//! Fig. 4-Left + Fig. 9 — cache-loading schemes.
//!
//! Paper: naive sequential loading inflates inference latency by ~102%
//! vs the ideal (free-loading) case; the bubble-free pipeline (Algo 1)
//! tracks the ideal closely. We serve identical single requests under
//! the four loader configurations and report inference latency, plus the
//! DP's predicted Fig.-9 timeline for the measured cost regime.

#[path = "common.rs"]
mod common;

use instgenie::cache::latency_model::LatencyModel;
use instgenie::cache::pipeline;
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::runtime::Manifest;
use instgenie::util::bench::{fmt_secs, Table};
use instgenie::workload::MaskDist;

fn measure(model: &str, ratio: f64, mutate: impl Fn(&mut EngineConfig)) -> f64 {
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.max_batch = 1;
    engine.prepost_cpu_us = 0;
    mutate(&mut engine);
    let cluster = common::launch(model, 1, engine, "request-lb", 1, true);
    common::serve_trace(cluster, 0.4, common::scaled(6), MaskDist::Fixed(ratio), 1, 5)
        .inference
        .p50
}

fn main() {
    let model = "sdxlm";
    let mut table = Table::new(
        "Fig. 4-Left: inference latency by cache-loading scheme (sdxlm)",
        &["mask_ratio", "naive", "strawman", "bubble-free", "ideal", "naive/ideal"],
    );
    for ratio in [0.05, 0.1, 0.2] {
        let naive = measure(model, ratio, |c| c.naive_loading = true);
        let strawman = measure(model, ratio, |c| c.force_all_cached = true);
        let dp = measure(model, ratio, |_| {});
        let ideal = measure(model, ratio, |c| c.sim_bandwidth = 0.0);
        table.rowf(&[
            &format!("{ratio:.2}"),
            &fmt_secs(naive),
            &fmt_secs(strawman),
            &fmt_secs(dp),
            &fmt_secs(ideal),
            &format!("+{:.0}%", (naive / ideal - 1.0) * 100.0),
        ]);
    }
    table.print();
    table.save_csv("fig4_cache_loading").ok();

    // Fig. 9: the DP's decisions. Two bandwidth regimes: the calibrated
    // default (load ~ cached compute; pipeline hides nearly everything)
    // and a slow-link regime (load >> cached compute; the DP interleaves
    // full blocks to absorb loads — the Fig. 9-Bottom mixing).
    let manifest = Manifest::load("artifacts").expect("artifacts");
    let cfg = manifest.model(model).unwrap().config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", model);
    let mut t9 = Table::new(
        "Fig. 9: pipeline schedules (predicted, per denoise step)",
        &["regime", "mask_ratio", "plan", "naive", "strawman", "bubble-free", "ideal"],
    );
    for (regime, bw_scale) in [("calibrated", 1.0f64), ("slow-link", 0.125)] {
        let mut lat_r = lat.clone();
        lat_r.load.slope /= bw_scale;
        for ratio in [0.05, 0.1, 0.2, 0.5] {
            let n = cfg.bucket_for((ratio * cfg.tokens as f64) as usize);
            let costs = lat_r.step_costs(&cfg, n, 1, instgenie::config::CacheMode::CacheY);
            let plan = pipeline::plan(&costs);
            let plan_str: String = plan
                .use_cache
                .iter()
                .map(|&u| if u { 'C' } else { 'F' })
                .collect();
            t9.rowf(&[
                &regime,
                &format!("{ratio:.2}"),
                &plan_str,
                &fmt_secs(pipeline::naive_latency(&costs)),
                &fmt_secs(pipeline::strawman_latency(&costs)),
                &fmt_secs(plan.latency),
                &fmt_secs(pipeline::ideal_latency(&costs)),
            ]);
        }
    }
    t9.print();
    t9.save_csv("fig9_pipeline").ok();

    // measured slow-link comparison: DP mixing vs forced all-cached
    let mut t_mix = Table::new(
        "Fig. 9-Bottom measured: slow link (bandwidth / 8), m = 0.05",
        &["scheme", "inference_p50"],
    );
    let bw = instgenie::config::EngineConfig::instgenie().sim_bandwidth / 8.0;
    let straw = measure(model, 0.05, |c| {
        c.sim_bandwidth = bw;
        c.force_all_cached = true;
    });
    let dp = measure(model, 0.05, |c| c.sim_bandwidth = bw);
    t_mix.rowf(&[&"strawman (all cached)", &fmt_secs(straw)]);
    t_mix.rowf(&[&"bubble-free DP", &fmt_secs(dp)]);
    t_mix.print();
    t_mix.save_csv("fig9_slowlink").ok();
}
