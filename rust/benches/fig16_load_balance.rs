//! Fig. 16-Right + Fig. 4-Right — load-balancing policies.
//!
//! Paper: under low per-worker traffic the policies tie; under higher
//! traffic, request- and token-granularity balancing misjudge the
//! mask-ratio-dependent compute + cache-loading load and inflate P95 tail
//! latency by up to 35%; the mask-aware policy (Algo 2) wins by up to 26%.

#[path = "common.rs"]
mod common;

use instgenie::config::{EngineConfig, SystemKind};
use instgenie::util::bench::{fmt_secs, Table};
use instgenie::workload::MaskDist;

fn main() {
    let model = std::env::var("INSTGENIE_BENCH_MODEL").unwrap_or_else(|_| "sdxlm".into());
    let workers = 4;
    let requests = common::scaled(80);
    let mut table = Table::new(
        &format!("Fig. 16-Right: load-balance policies ({model}, {workers} workers)"),
        &["rps/worker", "policy", "p95_e2e", "mean_e2e", "mean_queue"],
    );
    // public-trace masks: wide ratio variance stresses the balancers
    for rps_per_worker in [5.0, 12.0] { // low vs near-saturation traffic
        let rps = rps_per_worker * workers as f64;
        for sched in ["round-robin", "request-lb", "token-lb", "mask-aware"] {
            let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
            engine.max_batch = 4;
            engine.prepost_cpu_us = 500;
            let cluster = common::launch(&model, workers, engine, sched, 4, true);
            let rep = common::serve_trace(
                cluster,
                rps,
                requests,
                MaskDist::PublicTrace,
                4,
                33,
            );
            table.rowf(&[
                &format!("{rps_per_worker}"),
                &sched,
                &fmt_secs(rep.e2e.p95),
                &fmt_secs(rep.e2e.mean),
                &fmt_secs(rep.queue.mean),
            ]);
        }
    }
    table.print();
    table.save_csv("fig16_load_balance").ok();
}
