//! Shared plumbing for the paper-figure benches (harness = false).
//!
//! Each bench binary regenerates one table/figure of the paper's
//! evaluation, printing the same rows/series and saving CSV under
//! `bench_results/`. Scale is controlled by `INSTGENIE_BENCH_SCALE`
//! (default 1.0; raise for tighter statistics, lower for smoke runs).

#![allow(dead_code)]

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::metrics::{Recorder, Report};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::workload::{replay, MaskDist, TraceGen};

pub fn scale() -> f64 {
    std::env::var("INSTGENIE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()).round() as usize).max(4)
}

/// Launch a cluster with common bench defaults.
pub fn launch(
    model: &str,
    workers: usize,
    engine: EngineConfig,
    sched_name: &str,
    templates: usize,
    warmup: bool,
) -> Cluster {
    let manifest = Manifest::load("artifacts").expect("run `make artifacts` first");
    let mcfg = manifest.model(model).expect("model").config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", model);
    let sched = scheduler::by_name(sched_name, &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    Cluster::launch(
        ClusterOpts {
            workers,
            engine,
            model: model.into(),
            artifact_dir: "artifacts".into(),
            templates: (0..templates).map(|i| format!("tpl-{i}")).collect(),
            lat_model: lat,
            warmup,
        },
        sched,
    )
    .expect("cluster launch")
}

/// Run a Poisson trace through a cluster, returning the metrics report.
pub fn serve_trace(
    cluster: Cluster,
    rps: f64,
    requests: usize,
    dist: MaskDist,
    templates: usize,
    seed: u64,
) -> Report {
    let gen = TraceGen::new(rps, dist, templates, seed);
    let events = gen.generate(requests);
    let t0 = std::time::Instant::now();
    replay(&events, |ev| {
        cluster.submit_event(ev);
    });
    let ok = cluster.await_completed(events.len(), Duration::from_secs(900));
    assert!(ok, "serving timed out");
    let makespan = t0.elapsed().as_secs_f64();
    let responses = cluster.shutdown().expect("shutdown");
    let mut rec = Recorder::new();
    for r in &responses {
        rec.record(r);
    }
    rec.report(makespan)
}

/// One engine config per paper baseline (the §6 line-up).
pub fn systems() -> Vec<(&'static str, EngineConfig)> {
    vec![
        ("instgenie", EngineConfig::for_system(SystemKind::InstGenIE)),
        ("diffusers", EngineConfig::for_system(SystemKind::Diffusers)),
        ("fisedit", EngineConfig::for_system(SystemKind::FisEdit)),
        ("teacache", EngineConfig::for_system(SystemKind::TeaCache)),
    ]
}

/// Per-step coordinator overhead of a solo request stream (measured
/// step latency minus `pipeline::ideal_latency`); thin wrapper over the
/// shared [`instgenie::util::bench::measure_step_overhead`] recipe so
/// the microbench row and `BENCH_overhead.json` cannot drift apart.
/// `None` when artifacts are absent.
pub fn solo_step_overhead(device: bool) -> Option<f64> {
    instgenie::util::bench::measure_step_overhead("sd21m", device, scaled(4).min(16), 0.3)
        .expect("overhead measurement")
        .map(|s| s.overhead)
}
