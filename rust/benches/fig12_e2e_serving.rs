//! Fig. 12 — end-to-end request serving latency vs request rate, for all
//! four systems (the paper's headline comparison: up to 14.7x lower mean
//! latency than Diffusers, 4x vs FISEdit, 6x vs TeaCache), plus the
//! rightmost queuing-time bars.
//!
//! Testbed scale: 2 workers, production mask distribution, ~32 requests
//! per point (scale with INSTGENIE_BENCH_SCALE). Absolute numbers are
//! CPU-PJRT-scale; the comparison *shape* is the reproduction target.

#[path = "common.rs"]
mod common;

use instgenie::util::bench::{fmt_secs, Table};
use instgenie::workload::MaskDist;

fn main() {
    let full = std::env::var("INSTGENIE_BENCH_FULL").is_ok();
    let models: &[(&str, &[f64])] = if full {
        &[
            ("sd21m", &[2.0, 4.0, 8.0]),
            ("sdxlm", &[0.5, 1.0, 2.0]),
            ("fluxm", &[0.25, 0.5, 1.0]),
        ]
    } else {
        &[("sd21m", &[2.0, 6.0]), ("sdxlm", &[0.5, 1.5])]
    };
    let requests = common::scaled(32);

    let mut table = Table::new(
        "Fig. 12: end-to-end latency vs RPS (2 workers, production masks)",
        &["model", "rps", "system", "mean_e2e", "p95_e2e", "queue_mean", "tput"],
    );
    let mut queue_bars = Table::new(
        "Fig. 12-Rightmost: normalized queuing time at the top RPS",
        &["model", "system", "queue_norm"],
    );

    for (model, rates) in models {
        for &rps in *rates {
            let mut ig_queue = None;
            for (name, mut engine) in common::systems() {
                engine.prepost_cpu_us = 1000;
                let cluster = common::launch(model, 2, engine, "mask-aware", 4, true);
                let rep = common::serve_trace(
                    cluster,
                    rps,
                    requests,
                    MaskDist::Production,
                    4,
                    42,
                );
                table.rowf(&[
                    model,
                    &format!("{rps}"),
                    &name,
                    &fmt_secs(rep.e2e.mean),
                    &fmt_secs(rep.e2e.p95),
                    &fmt_secs(rep.queue.mean),
                    &format!("{:.2}", rep.throughput),
                ]);
                if rps == *rates.last().unwrap() {
                    let base = *ig_queue.get_or_insert(rep.queue.mean.max(1e-9));
                    queue_bars.rowf(&[
                        model,
                        &name,
                        &format!("{:.2}", rep.queue.mean / base),
                    ]);
                }
            }
        }
    }
    table.print();
    table.save_csv("fig12_e2e").ok();
    queue_bars.print();
    queue_bars.save_csv("fig12_queue_bars").ok();
}
