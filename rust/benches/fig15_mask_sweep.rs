//! Fig. 15 — mask-aware editing latency scales linearly with mask ratio.
//!
//! Left: kernel/block-level latency vs mask ratio (attention + linear
//! dominate a block; we time the full AOT block, the unit the pipeline
//! schedules). Right: image-level edit latency vs mask ratio, per model,
//! plus the speedup at m = 0.2 (paper: 1.3x / 2.2x / 1.9x for
//! SD2.1 / SDXL / Flux).

#[path = "common.rs"]
mod common;

use instgenie::config::{EngineConfig, SystemKind};
use instgenie::model::Latent;
use instgenie::runtime::ModelRuntime;
use instgenie::util::bench::{fmt_secs, time_it, Table};
use instgenie::util::stats::linear_fit;
use instgenie::workload::MaskDist;

fn main() {
    kernel_level();
    image_level();
}

fn kernel_level() {
    let mut table = Table::new(
        "Fig. 15-Left: block latency vs mask ratio (batch 1)",
        &["model", "mask_ratio", "tokens", "latency", "per_full"],
    );
    let mut csv = Table::new("csv", &["model", "ratio", "latency_s"]);
    for model in ["sd21m", "sdxlm", "fluxm"] {
        let rt = ModelRuntime::create("artifacts", model).expect("runtime");
        let cfg = rt.config.clone();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let full = {
            let x = Latent::noise(cfg.tokens, cfg.hidden, 1, 1.0);
            time_it(3, common::scaled(20), || {
                rt.run_block_y(0, cfg.tokens, 1, x.data()).unwrap();
            })
            .mean
        };
        for n in cfg.all_token_counts() {
            let x = Latent::noise(n, cfg.hidden, 1, 1.0);
            let s = time_it(3, common::scaled(20), || {
                rt.run_block_y(0, n, 1, x.data()).unwrap();
            });
            let ratio = n as f64 / cfg.tokens as f64;
            xs.push(ratio);
            ys.push(s.mean);
            table.rowf(&[
                &model,
                &format!("{ratio:.3}"),
                &n,
                &fmt_secs(s.mean),
                &format!("{:.2}x", s.mean / full),
            ]);
            csv.rowf(&[&model, &format!("{ratio:.4}"), &format!("{:.6e}", s.mean)]);
        }
        let fit = linear_fit(&xs, &ys);
        println!("  {model}: latency vs ratio linear fit R² = {:.4}", fit.r2);
    }
    table.print();
    csv.save_csv("fig15_kernel").ok();
}

fn image_level() {
    let mut table = Table::new(
        "Fig. 15-Right: image edit latency vs mask ratio (single request)",
        &["model", "mask_ratio", "instgenie", "full_regen", "speedup"],
    );
    let mut csv = Table::new("csv", &["model", "ratio", "instgenie_s", "full_s"]);
    for model in ["sd21m", "sdxlm", "fluxm"] {
        for ratio in [0.05, 0.1, 0.2, 0.4] {
            let ig = single_request_latency(model, SystemKind::InstGenIE, ratio);
            let full = single_request_latency(model, SystemKind::Diffusers, ratio);
            if (ratio - 0.2).abs() < 1e-9 {
                println!("  {model} @ m=0.2: speedup {:.2}x (paper: SD2.1 1.3x / SDXL 2.2x / Flux 1.9x)", full / ig);
            }
            table.rowf(&[
                &model,
                &format!("{ratio:.2}"),
                &fmt_secs(ig),
                &fmt_secs(full),
                &format!("{:.2}x", full / ig),
            ]);
            csv.rowf(&[
                &model,
                &format!("{ratio:.2}"),
                &format!("{ig:.6}"),
                &format!("{full:.6}"),
            ]);
        }
    }
    table.print();
    csv.save_csv("fig15_image").ok();
}

fn single_request_latency(model: &str, system: SystemKind, ratio: f64) -> f64 {
    let mut engine = EngineConfig::for_system(system);
    engine.max_batch = 1;
    engine.prepost_cpu_us = 0;
    let cluster = common::launch(model, 1, engine, "request-lb", 1, true);
    let report = common::serve_trace(
        cluster,
        0.35, // sequential-ish arrivals: isolate inference latency
        common::scaled(6),
        MaskDist::Fixed(ratio),
        1,
        9,
    );
    report.inference.p50
}
