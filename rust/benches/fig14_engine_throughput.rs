//! Fig. 14 — engine throughput vs max batch size.
//!
//! Paper: InstGenIE sustains throughput growth with batch size (up to 3x
//! the baselines at batch >= 2) because mask-aware inference shrinks each
//! request's compute; baselines plateau early. FISEdit cannot batch
//! (max 1); at batch 1, TeaCache can beat InstGenIE (it saturates the
//! device with all tokens while skipping steps) — both effects are
//! checked here. The queue is saturated up-front (offline throughput).

#[path = "common.rs"]
mod common;

use instgenie::util::bench::Table;
use instgenie::workload::MaskDist;

fn main() {
    let model = std::env::var("INSTGENIE_BENCH_MODEL").unwrap_or_else(|_| "sdxlm".into());
    let requests = common::scaled(32);
    let mut table = Table::new(
        &format!("Fig. 14: engine throughput vs batch size ({model}, saturated queue)"),
        &["system", "batch", "tput_req_s", "mean_inf"],
    );
    for (name, mut engine) in common::systems() {
        let batches: &[usize] = if name == "fisedit" { &[1] } else { &[1, 2, 4, 8] };
        for &b in batches {
            engine.max_batch = b;
            engine.prepost_cpu_us = 200;
            let cluster = common::launch(&model, 1, engine.clone(), "request-lb", 2, true);
            // saturate: all requests arrive (virtually) at once
            let rep = common::serve_trace(
                cluster,
                10_000.0,
                requests,
                MaskDist::Production,
                2,
                11,
            );
            table.rowf(&[
                &name,
                &b,
                &format!("{:.2}", rep.throughput),
                &instgenie::util::bench::fmt_secs(rep.inference.mean),
            ]);
        }
    }
    table.print();
    table.save_csv("fig14_engine_throughput").ok();
    occupancy_model(&model);
}

/// The paper's Fig.-14 mechanism needs an *underutilized parallel
/// device*: mask-aware inference leaves SMs idle at batch 1, so batching
/// is nearly free until the device saturates, while full-image baselines
/// saturate immediately. The single-core CPU testbed has no parallel
/// slack (batch compute is linear — EXPERIMENTS.md "Testbed deltas"), so
/// we additionally print the predicted throughput under a device-
/// occupancy model t_step(B, n) = T_sat * max(1, B*n/S) with saturation
/// at S = L tokens (Diffusers saturates exactly at batch 1), using the
/// calibrated T_sat.
fn occupancy_model(model: &str) {
    use instgenie::cache::LatencyModel;
    use instgenie::runtime::Manifest;
    let manifest = Manifest::load("artifacts").expect("artifacts");
    let cfg = manifest.model(model).unwrap().config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", model);
    let t_sat = lat.comp_seconds(instgenie::cache::latency_model::block_flops_full(&cfg))
        * cfg.blocks as f64;
    let s_tokens = cfg.tokens as f64;
    let mean_m = instgenie::workload::MaskDist::Production.mean();
    let n_ig = cfg.bucket_for((mean_m * cfg.tokens as f64).ceil() as usize) as f64;
    let mut t = Table::new(
        &format!("Fig. 14 (predicted, GPU occupancy model, {model})"),
        &["system", "batch", "tput_rel_b1"],
    );
    for (name, tokens_per_req, steps_scale) in [
        ("instgenie", n_ig, 1.0),
        ("diffusers", s_tokens, 1.0),
        ("teacache", s_tokens, 0.6), // ~40% steps skipped
    ] {
        let base = {
            let t_step = t_sat * (1f64).max(1.0 * tokens_per_req / s_tokens);
            1.0 / (t_step * cfg.steps as f64 * steps_scale)
        };
        for b in [1usize, 2, 4, 8] {
            let t_step = t_sat * (1f64).max(b as f64 * tokens_per_req / s_tokens);
            let tput = b as f64 / (t_step * cfg.steps as f64 * steps_scale);
            t.rowf(&[&name, &b, &format!("{:.2}", tput / base)]);
        }
    }
    t.rowf(&[&"fisedit", &1, &"1.00 (cannot batch)".to_string()]);
    t.print();
    t.save_csv("fig14_occupancy_model").ok();
}
