//! Fig. 3 + Fig. 11 + Table 1 — characterization & regression models.
//!
//! Fig. 3: mask-ratio distribution statistics (paper means 0.11 / 0.19 /
//! 0.35). Fig. 11: the latency regression models fit with R² ~ 0.99.
//! Table 1: the analytic FLOP/cache-shape scaling checked against
//! measured block latencies.

#[path = "common.rs"]
mod common;

use instgenie::cache::latency_model::{block_cache_bytes, block_flops_cached, block_flops_full, calibrate};
use instgenie::config::CacheMode;
use instgenie::runtime::ModelRuntime;
use instgenie::util::bench::Table;
use instgenie::util::rng::Pcg;
use instgenie::util::stats::Summary;
use instgenie::workload::MaskDist;

fn main() {
    fig3();
    table1();
    fig11();
}

fn fig3() {
    let mut table = Table::new(
        "Fig. 3: mask-ratio distributions",
        &["distribution", "mean", "p50", "p95", "paper_mean"],
    );
    for (dist, paper) in [
        (MaskDist::Production, 0.11),
        (MaskDist::PublicTrace, 0.19),
        (MaskDist::VitonHD, 0.35),
    ] {
        let mut rng = Pcg::new(1);
        let xs: Vec<f64> = (0..50_000).map(|_| dist.sample(&mut rng)).collect();
        let s = Summary::of(&xs);
        table.rowf(&[
            &format!("{dist:?}"),
            &format!("{:.3}", s.mean),
            &format!("{:.3}", s.p50),
            &format!("{:.3}", s.p95),
            &format!("{paper}"),
        ]);
    }
    table.print();
    table.save_csv("fig3_workload").ok();
}

fn table1() {
    let manifest = instgenie::runtime::Manifest::load("artifacts").expect("artifacts");
    let cfg = manifest.model("fluxm").unwrap().config.clone();
    let mut table = Table::new(
        "Table 1: mask-aware FLOP / cache scaling (fluxm, per block per member)",
        &["mask_ratio", "flops_ratio_y", "flops_ratio_kv", "cache_KiB_y", "expected_(1-m)LH"],
    );
    let full = block_flops_full(&cfg);
    for n in cfg.token_buckets.clone() {
        let m = n as f64 / cfg.tokens as f64;
        let fy = block_flops_cached(&cfg, n, CacheMode::CacheY) / full;
        let fkv = block_flops_cached(&cfg, n, CacheMode::CacheKV) / full;
        let bytes = block_cache_bytes(&cfg, n, CacheMode::CacheY);
        let expect = (cfg.tokens - n) as f64 * cfg.hidden as f64 * 4.0;
        table.rowf(&[
            &format!("{m:.3}"),
            &format!("{fy:.3}"),
            &format!("{fkv:.3}"),
            &format!("{:.1}", bytes / 1024.0),
            &format!("{:.1}", expect / 1024.0),
        ]);
    }
    table.print();
    table.save_csv("table1_scaling").ok();
}

fn fig11() {
    let mut table = Table::new(
        "Fig. 11: latency regression models (paper R² = 0.99)",
        &["model", "comp_slope_s_per_flop", "comp_r2", "load_slope_s_per_B", "load_r2"],
    );
    for model in ["sd21m", "sdxlm", "fluxm"] {
        let rt = ModelRuntime::create("artifacts", model).expect("runtime");
        let (lat, _, _) = calibrate(&rt, 192.0 * 1024.0 * 1024.0, common::scaled(10))
            .expect("calibrate");
        table.rowf(&[
            &model,
            &format!("{:.3e}", lat.comp.slope),
            &format!("{:.4}", lat.comp.r2),
            &format!("{:.3e}", lat.load.slope),
            &format!("{:.4}", lat.load.r2),
        ]);
        lat.save("artifacts", model).ok();
    }
    table.print();
    table.save_csv("fig11_regression").ok();
}
