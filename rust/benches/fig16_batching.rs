//! Fig. 16-Left + Fig. 4-Middle — batching strategies (§4.3 / §6.4).
//!
//! The paper's two effects, isolated for the single-core CPU testbed
//! (where batch compute is linear, unlike a GPU's ~1.29x-per-4 batch —
//! see EXPERIMENTS.md "Testbed deltas"):
//!
//! 1. **Queuing** (Fig. 4-Middle): static batching makes new arrivals
//!    wait for whole-batch completion; step-level continuous batching
//!    admits them in one denoise step. Paper: ~2x queuing reduction.
//! 2. **Interruptions** (Fig. 16-Left): the strawman continuous batcher
//!    runs CPU-bound pre/post-processing inline on the engine thread,
//!    interrupting the denoise loop (paper: up to 8 interruptions, +40%
//!    P95); disaggregation moves it to a separate pool (+0
//!    interruptions). Measured at batch 1 so batch-composition effects
//!    cannot confound the comparison.

#[path = "common.rs"]
mod common;

use instgenie::config::{BatchingPolicy, EngineConfig, SystemKind};
use instgenie::util::bench::{fmt_secs, Table};
use instgenie::workload::MaskDist;

fn main() {
    queuing();
    interruptions();
}

fn queuing() {
    let model = std::env::var("INSTGENIE_BENCH_MODEL").unwrap_or_else(|_| "sdxlm".into());
    let requests = common::scaled(60);
    let mut table = Table::new(
        &format!("Fig. 4-Middle: queuing time, static vs continuous ({model}, 1 worker)"),
        &["rps", "policy", "mean_queue", "p95_queue", "p95_e2e"],
    );
    for rps in [15.0, 30.0] {
        for (name, policy) in [
            ("static", BatchingPolicy::Static),
            ("continuous", BatchingPolicy::ContinuousDisaggregated),
        ] {
            let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
            engine.batching = policy;
            engine.max_batch = 4;
            engine.prepost_cpu_us = 1_000;
            let cluster = common::launch(&model, 1, engine, "request-lb", 3, true);
            let rep =
                common::serve_trace(cluster, rps, requests, MaskDist::Production, 3, 21);
            table.rowf(&[
                &format!("{rps}"),
                &name,
                &fmt_secs(rep.queue.mean),
                &fmt_secs(rep.queue.p95),
                &fmt_secs(rep.e2e.p95),
            ]);
        }
    }
    table.print();
    table.save_csv("fig4_mid_queuing").ok();
}

fn interruptions() {
    let model = std::env::var("INSTGENIE_BENCH_MODEL").unwrap_or_else(|_| "sdxlm".into());
    let requests = common::scaled(40);
    let mut table = Table::new(
        &format!("Fig. 16-Left: strawman vs disaggregated continuous batching ({model})"),
        &["policy", "interruptions/req", "mean_inf", "p95_e2e"],
    );
    // Same continuous policy + cap on both sides; only the *placement* of
    // pre/post-processing differs. On this 1-core testbed the latency
    // gain of disaggregation cannot materialize (there is no second core
    // to hide CPU work on), so the structural metric — how often the
    // denoise loop is interrupted — is the reproduction target; see
    // EXPERIMENTS.md "Testbed deltas".
    for (name, policy) in [
        ("strawman-cb (inline)", BatchingPolicy::ContinuousInline),
        ("instgenie-cb (disagg)", BatchingPolicy::ContinuousDisaggregated),
    ] {
        let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
        engine.batching = policy;
        engine.max_batch = 4;
        engine.prepost_cpu_us = 4_000;
        let cluster = common::launch(&model, 1, engine, "request-lb", 3, true);
        let rep = common::serve_trace(cluster, 25.0, requests, MaskDist::Production, 3, 22);
        table.rowf(&[
            &name,
            &format!("{:.1}", rep.mean_interruptions),
            &fmt_secs(rep.inference.mean),
            &fmt_secs(rep.e2e.p95),
        ]);
    }
    table.print();
    table.save_csv("fig16_batching").ok();
}
