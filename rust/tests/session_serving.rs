//! Integration: the session serving plane — sticky affinity, delta-mask
//! round reuse, SSE progress streaming, and the session lifecycle.
//!
//! All tests require `make artifacts` and skip silently otherwise (same
//! idiom as `cluster_serving.rs` / `dist_serving.rs`). The engine-free
//! registry mechanics (epoch bumps, orphaning, idle sweeps) are unit
//! tested in `src/session/mod.rs`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::tier::Residency;
use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts, RequestState, RoundError};
use instgenie::config::{CacheMode, EngineConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::engine::request::{EditRequest, EditRequestBuilder};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::session::{SessionError, SessionState};
use instgenie::templates::{RetireOutcome, TemplateState};
use instgenie::util::json::Json;

const MODEL: &str = "sd21m";

fn engine() -> EngineConfig {
    let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
    e.prepost_cpu_us = 200; // keep tests quick
    e.cache_mode = CacheMode::CacheKV; // exercise the KV reuse path
    e
}

/// In-process session-affinity cluster (None without artifacts).
fn session_cluster(workers: usize) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model(MODEL).ok()?.config.clone();
    let e = engine();
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let sched =
        scheduler::by_name("session-affinity", &mcfg, &lat, e.cache_mode, e.max_batch)
            .expect("scheduler");
    Cluster::launch(
        ClusterOpts {
            workers,
            engine: e,
            model: MODEL.into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into(), "tpl-1".into()],
            lat_model: lat,
            warmup: false,
        },
        sched,
    )
    .ok()
}

/// One session-round request: identical `(ratio, seed)` pairs realize
/// bit-identical masks, which is what makes a round warm.
fn round_request(id: u64, hw: usize, ratio: f64, seed: u64) -> EditRequest {
    EditRequestBuilder::new(id)
        .template("tpl-0")
        .prompt_seed(seed)
        .synth_mask(hw, ratio)
        .expect("mask")
        .build()
        .expect("request")
}

fn latent_hw() -> Option<usize> {
    Some(Manifest::load("artifacts").ok()?.model(MODEL).ok()?.config.latent_hw)
}

/// Worker `w`'s cumulative KV host->device upload bytes. The engine
/// publishes transfer counters just after each step resolves, so settle
/// briefly before sampling.
fn kv_h2d(cluster: &Cluster, w: usize) -> u64 {
    std::thread::sleep(Duration::from_millis(200));
    cluster.worker_snapshots()[w].transfers.kv_h2d_bytes
}

/// Acceptance (a): rounds with an unchanged mask are warm, stick to the
/// session owner's worker, move zero KV upload bytes, and still produce
/// bit-identical results.
#[test]
fn warm_rounds_stick_to_owner_with_zero_kv_upload() {
    let Some(cluster) = session_cluster(2) else { return };
    let hw = latent_hw().unwrap();
    let sid = cluster.open_session("tpl-0").expect("open");

    let (t1, p1) = cluster
        .submit_session_round(sid, round_request(1, hw, 0.3, 7))
        .expect("round 1");
    assert_eq!(p1.round, 1);
    assert!(!p1.warm, "round 1 has no prior mask and must be cold");
    let owner = t1.worker();
    let r1 = t1.wait(Duration::from_secs(120)).expect("round 1 completes");
    let kv_after_cold = kv_h2d(&cluster, owner);

    for (i, id) in [(2u64, 2u64), (3, 3)] {
        let (t, p) = cluster
            .submit_session_round(sid, round_request(id, hw, 0.3, 7))
            .expect("warm round");
        assert_eq!(p.round, i);
        assert!(p.warm, "round {i} repeats the mask and must be warm");
        assert_eq!(
            t.worker(),
            owner,
            "round {i} must stick to the session owner's worker"
        );
        let r = t.wait(Duration::from_secs(120)).expect("warm round completes");
        assert_eq!(
            r.latent.data(),
            r1.latent.data(),
            "KV reuse must not change the result"
        );
    }
    let kv_after_warm = kv_h2d(&cluster, owner);
    assert_eq!(
        kv_after_warm, kv_after_cold,
        "warm rounds must perform zero KV H2D uploads"
    );
    let st = cluster.close_session(sid, Duration::from_secs(30)).expect("close");
    assert_eq!(st.state, SessionState::Closed);
    cluster.shutdown().expect("shutdown");
}

/// Satellite: closing a session with a round still in flight drains it
/// before releasing the template pin, and refuses further rounds.
#[test]
fn close_with_inflight_round_drains_before_release() {
    let Some(cluster) = session_cluster(1) else { return };
    let hw = latent_hw().unwrap();
    let sid = cluster.open_session("tpl-0").expect("open");
    let (ticket, _) = cluster
        .submit_session_round(sid, round_request(10, hw, 0.25, 3))
        .expect("round");
    // close immediately: the round is still queued/running
    let st = cluster.close_session(sid, Duration::from_secs(60)).expect("close");
    assert_eq!(st.state, SessionState::Closed);
    assert_eq!(st.inflight, 0, "close must drain the in-flight round");
    assert_eq!(st.rounds.len(), 1);
    assert_eq!(st.rounds[0].ok, Some(true), "the drained round completed");
    assert!(st.rounds[0].latency.is_some());
    // the ticket resolved normally — close never cancels in-flight work
    ticket.wait(Duration::from_secs(5)).expect("round result retained");
    // further rounds are refused with the typed lifecycle error
    match cluster.submit_session_round(sid, round_request(11, hw, 0.25, 3)) {
        Err(RoundError::Session(SessionError::NotOpen { state, .. })) => {
            assert_eq!(state, "closed");
        }
        other => panic!("round after close must be refused, got {other:?}"),
    }
    cluster.shutdown().expect("shutdown");
}

/// Satellite: idle expiry releases the session's template pin so a
/// pending retirement drains, purging worker tiers behind it.
#[test]
fn idle_expiry_releases_template_pin_and_retire_purges() {
    let Some(cluster) = session_cluster(1) else { return };
    let hw = latent_hw().unwrap();
    let sid = cluster.open_session("tpl-0").expect("open");
    let (t, _) = cluster
        .submit_session_round(sid, round_request(20, hw, 0.2, 5))
        .expect("round");
    t.wait(Duration::from_secs(120)).expect("round completes");

    // a fresh sweep at 'now' expires nothing (the session is not idle yet)
    assert_eq!(cluster.expire_idle_sessions(), 0);
    // simulate the idle window elapsing
    let later = Instant::now() + Duration::from_secs(700);
    assert_eq!(cluster.expire_idle_sessions_at(later), 1);
    assert_eq!(cluster.expire_idle_sessions_at(later), 0, "sweep is idempotent");
    let st = cluster.session_status(sid).expect("status survives expiry");
    assert_eq!(st.state, SessionState::Expired);
    match cluster.submit_session_round(sid, round_request(21, hw, 0.2, 5)) {
        Err(RoundError::Session(SessionError::NotOpen { state, .. })) => {
            assert_eq!(state, "expired");
        }
        other => panic!("round after expiry must be refused, got {other:?}"),
    }

    // a second session's pin holds a retirement draining until expiry
    // releases it — then the purge lands on the worker tiers
    let sid2 = cluster.open_session("tpl-0").expect("open second");
    match cluster.retire_template("tpl-0") {
        RetireOutcome::Draining { inflight } => assert_eq!(inflight, 1),
        other => panic!("session pin must hold the retirement, got {other:?}"),
    }
    let later2 = Instant::now() + Duration::from_secs(700);
    assert_eq!(cluster.expire_idle_sessions_at(later2), 1);
    assert_eq!(
        cluster.session_status(sid2).map(|s| s.state),
        Some(SessionState::Expired)
    );
    let tst = cluster.template_status("tpl-0").expect("template status");
    assert_eq!(tst.info.state, TemplateState::Retired);
    assert!(
        tst.residency.iter().all(|r| matches!(r, Residency::Absent)),
        "expiry must have drained the retirement and purged the tiers"
    );
    cluster.shutdown().expect("shutdown");
}

// ---------------------------------------------------------------------
// Distributed plane: affinity re-homing on drain and owner death.
// ---------------------------------------------------------------------

fn node_opts() -> Option<ClusterOpts> {
    Manifest::load("artifacts").ok()?;
    Some(ClusterOpts {
        workers: 1,
        engine: engine(),
        model: MODEL.into(),
        artifact_dir: "artifacts".into(),
        templates: vec!["tpl-0".into(), "tpl-1".into()],
        lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
        warmup: false,
    })
}

/// Router + N worker nodes over loopback TCP with sticky routing.
fn dist_plane(workers: usize) -> Option<(Arc<Router>, Vec<Arc<WorkerNode>>)> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model(MODEL).ok()?.config.clone();
    let cfg = DistConfig::fast();
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let e = engine();
    let sched =
        scheduler::by_name("session-affinity", &mcfg, &lat, e.cache_mode, e.max_batch)
            .expect("scheduler");
    let router = Router::new(mcfg, sched, None, cfg.clone());
    let addr = router.start("127.0.0.1:0").expect("router start");
    let mut nodes = Vec::new();
    for i in 0..workers {
        let node = Arc::new(WorkerNode::launch(format!("w{i}"), node_opts()?).expect("node"));
        node.start("127.0.0.1:0").expect("node start");
        node.announce_to(&addr.to_string(), &cfg);
        nodes.push(node);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.ready_count() < workers {
        assert!(
            Instant::now() < deadline,
            "workers never became ready at the router"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    Some((router, nodes))
}

/// Wait for a router-submitted request to finish and hand back its full
/// response (the registry retains the tensors the HTTP body summarizes).
fn wait_done(router: &Router, id: u64) -> Arc<instgenie::engine::request::EditResponse> {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Some(st) = router.registry().status(id) {
            match st.state {
                RequestState::Done(resp) => return resp,
                RequestState::Failed(e) => panic!("request {id} failed: {e:?}"),
                _ => {}
            }
        }
        assert!(Instant::now() < deadline, "request {id} never completed");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Submit one session round over the router's HTTP surface; returns the
/// 202 body (id, worker slot, warm flag).
fn post_round(router: &Router, sid: u64, ratio: f64, seed: u64) -> Json {
    let body = format!("{{\"mask_ratio\": {ratio}, \"prompt_seed\": {seed}}}");
    let (status, reply) = router.route("POST", &format!("/v1/sessions/{sid}/rounds"), &body);
    assert_eq!(status, 202, "round must be accepted: {reply}");
    reply
}

fn wait_member_state(router: &Router, name: &str, want: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, body) = router.route("GET", "/v1/cluster", "");
        let hit = body
            .at("members")
            .as_arr()
            .map(|ms| {
                ms.iter().any(|m| {
                    m.at("name").as_str() == Some(name)
                        && m.at("state").as_str() == Some(want)
                })
            })
            .unwrap_or(false);
        if hit {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "member {name} never reached state {want}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Acceptance (b): killing the session owner mid-session re-homes the
/// following rounds onto the surviving worker, bit-identical to the
/// pre-kill (solo) result, with the session epoch bumped.
#[test]
fn killing_session_owner_rehomes_rounds_bit_identically() {
    let Some((router, nodes)) = dist_plane(2) else { return };
    let (status, reply) = router.route("POST", "/v1/sessions", r#"{"template": "tpl-0"}"#);
    assert_eq!(status, 201, "{reply}");
    let sid = reply.at("session").as_usize().expect("session id") as u64;

    let r1 = post_round(&router, sid, 0.3, 7);
    let owner = r1.at("worker").as_usize().expect("worker slot");
    let resp1 = wait_done(&router, r1.at("id").as_usize().unwrap() as u64);

    // kill the owner with the session live: heartbeats stop, the failure
    // detector fires, and the registry orphans the session
    nodes[owner].stop();
    wait_member_state(&router, &format!("w{owner}"), "dead");

    let r2 = post_round(&router, sid, 0.3, 7);
    let rehomed = r2.at("worker").as_usize().expect("worker slot");
    assert_ne!(rehomed, owner, "the dead owner cannot serve the round");
    assert_eq!(r2.at("warm").as_bool(), Some(true), "the mask is unchanged");
    let resp2 = wait_done(&router, r2.at("id").as_usize().unwrap() as u64);
    assert_eq!(
        resp1.latent.data(),
        resp2.latent.data(),
        "re-homed rounds must be bit-identical to the solo run"
    );

    let (_, st) = router.route("GET", &format!("/v1/sessions/{sid}"), "");
    assert_eq!(st.at("owner").as_usize(), Some(rehomed));
    assert!(
        st.at("epoch").as_usize().unwrap_or(0) >= 2,
        "re-homing must bump the session epoch"
    );
    router.shutdown();
    nodes[rehomed].stop();
}

/// Satellite: a round submitted while the owner is live-draining re-homes
/// onto the other member and stays bit-identical.
#[test]
fn round_while_owner_draining_rehomes_bit_identically() {
    let Some((router, nodes)) = dist_plane(2) else { return };
    let (status, reply) = router.route("POST", "/v1/sessions", r#"{"template": "tpl-1"}"#);
    assert_eq!(status, 201, "{reply}");
    let sid = reply.at("session").as_usize().expect("session id") as u64;

    let r1 = post_round(&router, sid, 0.2, 11);
    let owner = r1.at("worker").as_usize().expect("worker slot");
    let resp1 = wait_done(&router, r1.at("id").as_usize().unwrap() as u64);

    let (status, _) = router.route("POST", &format!("/v1/drain/w{owner}"), "");
    assert_eq!(status, 200);
    wait_member_state(&router, &format!("w{owner}"), "draining");

    let r2 = post_round(&router, sid, 0.2, 11);
    let rehomed = r2.at("worker").as_usize().expect("worker slot");
    assert_ne!(rehomed, owner, "a draining owner takes no new rounds");
    let resp2 = wait_done(&router, r2.at("id").as_usize().unwrap() as u64);
    assert_eq!(
        resp1.latent.data(),
        resp2.latent.data(),
        "re-homing around a drain must not change the result"
    );
    router.shutdown();
    for n in &nodes {
        n.stop();
    }
}

// ---------------------------------------------------------------------
// SSE progress streaming over the HTTP frontend.
// ---------------------------------------------------------------------

fn http(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn post(addr: &str, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn body_json(resp: &str) -> Json {
    Json::parse(resp.split("\r\n\r\n").nth(1).expect("body")).expect("json body")
}

/// Parse an SSE response into `(event_kind, data_json)` frames.
fn sse_frames(resp: &str) -> Vec<(String, Json)> {
    let body = resp.split("\r\n\r\n").nth(1).expect("sse body");
    body.split("\n\n")
        .filter(|f| !f.trim().is_empty())
        .map(|frame| {
            let mut kind = String::new();
            let mut data = Json::Null;
            for line in frame.lines() {
                if let Some(k) = line.strip_prefix("event: ") {
                    kind = k.to_string();
                } else if let Some(d) = line.strip_prefix("data: ") {
                    data = Json::parse(d).expect("sse data json");
                }
            }
            (kind, data)
        })
        .collect()
}

/// Launch an in-process cluster + HTTP frontend; keeps a cluster handle
/// for buffer-leak assertions.
fn serve_sessions(addr: &str) -> Option<(Arc<HttpServer>, Arc<Cluster>)> {
    let cluster = Arc::new(session_cluster(1)?);
    let server = Arc::new(HttpServer::new(Arc::clone(&cluster), 1));
    {
        let server = Arc::clone(&server);
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let _ = server.serve(&addr);
        });
    }
    std::thread::sleep(Duration::from_millis(100));
    Some((server, cluster))
}

fn await_no_progress_buffers(cluster: &Cluster) {
    let shared = cluster.worker_shared(0).expect("worker 0");
    let deadline = Instant::now() + Duration::from_secs(10);
    while shared.progress_rounds() > 0 {
        assert!(
            Instant::now() < deadline,
            "progress buffers leaked: {} rounds still held",
            shared.progress_rounds()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Acceptance (c): the SSE stream delivers monotone step events and a
/// terminal done event, then releases the round's buffer.
#[test]
fn sse_streams_monotone_steps_then_done() {
    let addr = "127.0.0.1:18931";
    let Some((_server, cluster)) = serve_sessions(addr) else { return };
    let reply = body_json(&post(addr, "/v1/sessions", r#"{"template": "tpl-0"}"#));
    let sid = reply.at("session").as_usize().expect("sid");
    let round = body_json(&post(
        addr,
        &format!("/v1/sessions/{sid}/rounds"),
        r#"{"mask_ratio": 0.3, "prompt_seed": 7}"#,
    ));
    let events_url = round.at("events_url").as_str().expect("events url").to_string();

    // attach after completion or mid-flight — the bounded buffer replays
    // either way, ending with the terminal event
    let resp = http(addr, &format!("GET {events_url} HTTP/1.1\r\nHost: x\r\n\r\n"));
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("Content-Type: text/event-stream"), "{resp}");
    let frames = sse_frames(&resp);
    assert!(frames.len() >= 2, "expected step events plus done, got {frames:?}");
    let steps = &frames[..frames.len() - 1];
    assert!(steps.iter().all(|(k, _)| k == "step"));
    for w in steps.windows(2) {
        assert!(
            w[1].1.at("seq").as_usize() > w[0].1.at("seq").as_usize(),
            "seq must be strictly monotone"
        );
        assert!(
            w[1].1.at("step").as_usize() > w[0].1.at("step").as_usize(),
            "step must be strictly monotone"
        );
    }
    let (kind, data) = frames.last().unwrap();
    assert_eq!(kind, "done", "the stream must end with the terminal event");
    assert_eq!(data.at("done").as_bool(), Some(true));
    // the server dropped the round's buffer when the stream ended
    await_no_progress_buffers(&cluster);
}

/// Satellite: a client that disconnects early never leaks the round's
/// buffer, and the engine is never blocked on the consumer (the next
/// round completes normally).
#[test]
fn sse_client_disconnect_does_not_leak_buffers() {
    let addr = "127.0.0.1:18932";
    let Some((_server, cluster)) = serve_sessions(addr) else { return };
    let reply = body_json(&post(addr, "/v1/sessions", r#"{"template": "tpl-0"}"#));
    let sid = reply.at("session").as_usize().expect("sid");
    let round = body_json(&post(
        addr,
        &format!("/v1/sessions/{sid}/rounds"),
        r#"{"mask_ratio": 0.2, "prompt_seed": 9}"#,
    ));
    let events_url = round.at("events_url").as_str().expect("events url").to_string();

    // connect, read only the status line, then hang up
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(format!("GET {events_url} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut first = [0u8; 16];
        s.read_exact(&mut first).expect("status line");
        // dropped here: the server's next write fails (or the stream ends
        // on the terminal event) — either exit path drops the buffer
    }

    // a second round is unaffected by the abandoned consumer
    let round2 = body_json(&post(
        addr,
        &format!("/v1/sessions/{sid}/rounds"),
        r#"{"mask_ratio": 0.2, "prompt_seed": 9}"#,
    ));
    assert_eq!(round2.at("warm").as_bool(), Some(true));
    let resp = http(
        addr,
        &format!(
            "GET /v1/sessions/{sid}/rounds/{}/events HTTP/1.1\r\nHost: x\r\n\r\n",
            round2.at("round").as_usize().unwrap()
        ),
    );
    let frames = sse_frames(&resp);
    assert_eq!(frames.last().map(|(k, _)| k.as_str()), Some("done"));
    await_no_progress_buffers(&cluster);
}
