//! Golden: the device-resident step loop (upload once per contiguous
//! same-mode block run, chain `PjRtBuffer`s device-to-device, download
//! once) is **bit-identical** to the host-round-trip reference loop
//! (`device_resident: false` — per-block upload/scatter/gather/download)
//! across `SystemKind` x `CacheMode` x batching scenarios.
//!
//! Requires `make artifacts`; tests skip silently otherwise.
//!
//! Determinism notes: multi-member scenarios use equal mask ratios (the
//! token bucket, and with it each member's compute set, is then
//! independent of join timing) and either full-sequence systems or
//! `force_all_cached` (the plan is then composition-independent), so the
//! two runs are comparable bit-for-bit even though continuous-batching
//! join steps are wall-clock dependent.

use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts, RequestState};
use instgenie::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
use instgenie::engine::request::{EditRequest, EditRequestBuilder};
use instgenie::runtime::{ArtifactRoot, Manifest, TransferTotals};
use instgenie::scheduler;

const MODEL: &str = "sd21m";

#[derive(Clone, Copy)]
struct Scenario {
    system: SystemKind,
    mode: CacheMode,
    /// Override the system's default batching policy.
    batching: Option<BatchingPolicy>,
    force_all_cached: bool,
    /// Slow the copy stream (widens step windows for join scenarios).
    bandwidth: Option<f64>,
    /// Override the device KV tier's HBM budget (None = engine default;
    /// Some(0) disables the tier).
    kv_budget: Option<usize>,
}

fn launch(sc: Scenario, device_resident: bool) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model(MODEL).ok()?.config.clone();
    let mut engine = EngineConfig::for_system(sc.system);
    engine.cache_mode = sc.mode;
    engine.device_resident = device_resident;
    engine.force_all_cached = sc.force_all_cached;
    engine.prepost_cpu_us = 50;
    if let Some(b) = sc.batching {
        engine.batching = b;
    }
    if let Some(bw) = sc.bandwidth {
        engine.sim_bandwidth = bw;
    }
    if let Some(budget) = sc.kv_budget {
        engine.kv_device_budget_bytes = budget;
    }
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let sched = scheduler::by_name("round-robin", &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    Some(
        Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: MODEL.into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-golden".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .expect("launch"),
    )
}

fn edit(cluster: &Cluster, id: u64, seed: u64, ratio: f64) -> EditRequest {
    let hw = cluster.model.latent_hw;
    EditRequestBuilder::new(id)
        .template("tpl-golden")
        .prompt_seed(seed)
        .synth_mask(hw, ratio)
        .expect("ratio")
        .build()
        .expect("valid request")
}

fn await_running(cluster: &Cluster, id: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        match cluster.status(id).map(|s| s.state) {
            Some(RequestState::Running) => return,
            Some(RequestState::Queued) => {}
            other => panic!("request {id} left the queue unexpectedly: {other:?}"),
        }
        assert!(Instant::now() < deadline, "request {id} never started");
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// Run `requests` (id, seed, ratio) through one cluster; `stagger` waits
/// for the previous request to be running before submitting the next
/// (the mid-batch-join scenario). Returns (id, latent bits, image bits)
/// per request. `None` = artifacts not built.
fn run_scenario(
    sc: Scenario,
    device_resident: bool,
    requests: &[(u64, u64, f64)],
    stagger: bool,
) -> Option<Vec<(u64, Vec<u32>, Vec<u32>)>> {
    let cluster = launch(sc, device_resident)?;
    let mut tickets = Vec::new();
    for (i, &(id, seed, ratio)) in requests.iter().enumerate() {
        if stagger && i > 0 {
            await_running(&cluster, requests[i - 1].0);
        }
        tickets.push(
            cluster
                .submit_checked(edit(&cluster, id, seed, ratio))
                .expect("submit"),
        );
    }
    let mut out = Vec::new();
    for t in tickets {
        let id = t.id();
        let resp = t.wait(Duration::from_secs(300)).expect("completed");
        let latent: Vec<u32> = resp.latent.data().iter().map(|v| v.to_bits()).collect();
        let image: Vec<u32> = resp.image.data().iter().map(|v| v.to_bits()).collect();
        out.push((id, latent, image));
    }
    cluster.shutdown().expect("shutdown");
    Some(out)
}

/// Device loop vs host reference on identical request streams.
fn assert_bit_identical(sc: Scenario, requests: &[(u64, u64, f64)], stagger: bool, label: &str) {
    let Some(dev) = run_scenario(sc, true, requests, stagger) else { return };
    let host = run_scenario(sc, false, requests, stagger).expect("artifacts vanished mid-test");
    assert_eq!(dev.len(), host.len(), "{label}: result count");
    for ((id_d, lat_d, img_d), (id_h, lat_h, img_h)) in dev.iter().zip(&host) {
        assert_eq!(id_d, id_h, "{label}: result order");
        assert_eq!(
            lat_d, lat_h,
            "{label}: latent bits differ for request {id_d}"
        );
        assert_eq!(
            img_d, img_h,
            "{label}: image bits differ for request {id_d}"
        );
    }
}

#[test]
fn solo_static_all_system_kinds_both_cache_modes() {
    // One request per cluster: fully deterministic, covers step_masked
    // (InstGenIE: real DP plan with cached<->full transitions; FisEdit:
    // free loads, all-cached plan) and step_full (Diffusers; TeaCache
    // incl. gate replay) in both cache modes.
    for system in [
        SystemKind::InstGenIE,
        SystemKind::Diffusers,
        SystemKind::FisEdit,
        SystemKind::TeaCache,
    ] {
        for mode in [CacheMode::CacheY, CacheMode::CacheKV] {
            let sc = Scenario {
                system,
                mode,
                batching: Some(BatchingPolicy::Static),
                force_all_cached: false,
                bandwidth: None,
                kv_budget: None,
            };
            let label = format!("{:?}/{:?}", system, mode);
            assert_bit_identical(sc, &[(1, 77, 0.3)], false, &label);
        }
    }
}

#[test]
fn continuous_mid_batch_join_is_bit_identical() {
    // Continuous batching with staggered submissions: members join the
    // running batch at step boundaries. Equal ratios keep the token
    // bucket stable and force_all_cached keeps the plan composition-
    // independent, so join timing cannot change the math — the device
    // chain must match the host reference bit-for-bit per member.
    for mode in [CacheMode::CacheY, CacheMode::CacheKV] {
        let sc = Scenario {
            system: SystemKind::InstGenIE,
            mode,
            batching: None, // ContinuousDisaggregated (InstGenIE default)
            force_all_cached: true,
            bandwidth: Some(8.0 * 1024.0 * 1024.0),
            kv_budget: None,
        };
        let reqs = [(1, 11, 0.25), (2, 22, 0.25), (3, 33, 0.25)];
        assert_bit_identical(sc, &reqs, true, &format!("join/{mode:?}"));
    }
}

#[test]
fn static_batched_full_mode_is_bit_identical() {
    // Multi-member full-sequence batches (padding slots, batch buckets):
    // full mode is member-independent, so join-timing races cannot leak
    // into the outputs even under static batching.
    for system in [SystemKind::Diffusers, SystemKind::TeaCache] {
        let sc = Scenario {
            system,
            mode: CacheMode::CacheY,
            batching: None, // Static (baseline default)
            force_all_cached: false,
            bandwidth: None,
            kv_budget: None,
        };
        let reqs = [(1, 5, 0.2), (2, 6, 0.2)];
        assert_bit_identical(sc, &reqs, false, &format!("{system:?}/batched"));
    }
}

#[test]
fn device_loop_cuts_transfers_per_step() {
    // The acceptance bound on the live path: with the device-resident
    // loop, per-step transfer ops are <= 2 per contiguous same-mode run
    // (+2 KV uploads per cached block in KV mode). force_all_cached +
    // CacheY = one run per step = exactly 2 transfer ops per step; the
    // host reference pays 2 per *block*.
    // pre-v4 tuple-root artifacts cannot chain: the device loop falls
    // back to host stepping (bit-identity still holds, but the transfer
    // bound doesn't apply) — skip
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let chainable = manifest
        .model(MODEL)
        .map(|m| m.artifacts.iter().any(|a| a.root == ArtifactRoot::Array))
        .unwrap_or(false);
    if !chainable {
        return;
    }
    let sc = Scenario {
        system: SystemKind::InstGenIE,
        mode: CacheMode::CacheY,
        batching: Some(BatchingPolicy::Static),
        force_all_cached: true,
        bandwidth: None,
        kv_budget: None,
    };
    let measure = |device: bool| -> Option<(f64, usize)> {
        let cluster = launch(sc, device)?;
        let t = cluster
            .submit_checked(edit(&cluster, 1, 9, 0.3))
            .expect("submit");
        t.wait(Duration::from_secs(300)).expect("completed");
        // the engine publishes transfer totals just *after* the step that
        // completed the request resolves its ticket — let it land
        std::thread::sleep(Duration::from_millis(200));
        let snap = &cluster.worker_snapshots()[0];
        let ops = (snap.transfers.h2d_ops + snap.transfers.d2h_ops) as f64;
        let steps = snap.steps_executed.max(1);
        let blocks = cluster.model.blocks;
        cluster.shutdown().expect("shutdown");
        Some((ops / steps as f64, blocks))
    };
    let Some((dev_ops_per_step, blocks)) = measure(true) else { return };
    let (host_ops_per_step, _) = measure(false).expect("artifacts vanished mid-test");
    assert!(
        dev_ops_per_step <= 2.0 + 1e-9,
        "device loop: {dev_ops_per_step} transfer ops/step (want <= 2)"
    );
    assert!(
        (host_ops_per_step - 2.0 * blocks as f64).abs() < 1e-9,
        "host reference: {host_ops_per_step} ops/step (want 2 x {blocks} blocks)"
    );
}

/// Run requests strictly one at a time through a single cluster (submit,
/// wait for completion, then submit the next) so every step is a solo
/// batch — the regime where the device KV tier engages. Returns the per-
/// request output bits plus the cumulative transfer totals snapshotted
/// after each request. `None` = artifacts not built.
#[allow(clippy::type_complexity)]
fn run_sequential(
    sc: Scenario,
    device_resident: bool,
    requests: &[(u64, u64, f64)],
) -> Option<(Vec<(u64, Vec<u32>, Vec<u32>)>, Vec<TransferTotals>)> {
    let cluster = launch(sc, device_resident)?;
    let mut out = Vec::new();
    let mut totals = Vec::new();
    for &(id, seed, ratio) in requests {
        let t = cluster
            .submit_checked(edit(&cluster, id, seed, ratio))
            .expect("submit");
        let resp = t.wait(Duration::from_secs(300)).expect("completed");
        // transfer totals publish just after the final step resolves the
        // ticket — let them land before snapshotting
        std::thread::sleep(Duration::from_millis(200));
        let latent: Vec<u32> = resp.latent.data().iter().map(|v| v.to_bits()).collect();
        let image: Vec<u32> = resp.image.data().iter().map(|v| v.to_bits()).collect();
        out.push((id, latent, image));
        totals.push(cluster.worker_snapshots()[0].transfers);
    }
    cluster.shutdown().expect("shutdown");
    Some((out, totals))
}

#[test]
fn device_kv_tier_bit_identity_warm_cold_and_evicting() {
    // The mask is a deterministic function of the prompt seed, so
    // repeating one seed repeats the cached-row set exactly — request 1
    // populates the device KV tier and requests 2..n replay it warm.
    // Whatever the tier does (serve warm, churn under a tiny budget that
    // forces mid-trace eviction, or sit disabled at budget 0), the output
    // bits must match the host-reference loop exactly.
    let reqs = [(1, 77, 0.3), (2, 77, 0.3), (3, 77, 0.3)];
    let base = Scenario {
        system: SystemKind::InstGenIE,
        mode: CacheMode::CacheKV,
        batching: Some(BatchingPolicy::Static),
        force_all_cached: false,
        bandwidth: None,
        kv_budget: None,
    };
    let Some((host, _)) = run_sequential(base, false, &reqs) else { return };
    let budgets: [(&str, Option<usize>); 3] = [
        ("warm", None),              // default budget: whole trace resident
        ("evicting", Some(48 << 10)), // a few entries: LRU churns mid-trace
        ("disabled", Some(0)),       // tier off: pure upload path
    ];
    for (label, budget) in budgets {
        let sc = Scenario { kv_budget: budget, ..base };
        let (dev, _) = run_sequential(sc, true, &reqs).expect("artifacts vanished mid-test");
        for ((id_d, lat_d, img_d), (id_h, lat_h, img_h)) in dev.iter().zip(&host) {
            assert_eq!(id_d, id_h, "kv-tier/{label}: result order");
            assert_eq!(
                lat_d, lat_h,
                "kv-tier/{label}: latent bits differ for request {id_d}"
            );
            assert_eq!(
                img_d, img_h,
                "kv-tier/{label}: image bits differ for request {id_d}"
            );
        }
    }
}

#[test]
fn warm_template_steady_state_kv_uploads_are_zero() {
    // The tentpole acceptance bound: once a template's K/V trace is
    // resident in the device tier, a repeat request performs *zero*
    // host->device KV transfers — every cached block is a tier hit.
    // Needs chainable artifacts (otherwise the device loop falls back to
    // host stepping and the KV counters stay zero) — skip like the
    // transfer-ops test above.
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let chainable = manifest
        .model(MODEL)
        .map(|m| m.artifacts.iter().any(|a| a.root == ArtifactRoot::Array))
        .unwrap_or(false);
    if !chainable {
        return;
    }
    let sc = Scenario {
        system: SystemKind::InstGenIE,
        mode: CacheMode::CacheKV,
        batching: Some(BatchingPolicy::Static),
        force_all_cached: true,
        bandwidth: None,
        kv_budget: None,
    };
    let reqs = [(1, 9, 0.3), (2, 9, 0.3)];
    let Some((bits, totals)) = run_sequential(sc, true, &reqs) else { return };
    assert_eq!(bits[0].1, bits[1].1, "same seed must reproduce the same latent");
    let (cold, warm) = (&totals[0], &totals[1]);
    assert!(cold.kv_dev_misses > 0, "cold request must populate the tier");
    assert!(cold.kv_h2d_bytes > 0, "cold request uploads staged K/V");
    assert_eq!(
        warm.kv_h2d_bytes, cold.kv_h2d_bytes,
        "warm request must perform zero KV uploads (steady state)"
    );
    assert_eq!(
        warm.kv_dev_misses, cold.kv_dev_misses,
        "warm request must never miss the device tier"
    );
    assert!(
        warm.kv_dev_hits > cold.kv_dev_hits,
        "warm request is served from the device tier"
    );
}
