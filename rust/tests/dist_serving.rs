//! Integration: the distributed serving plane — router + worker nodes
//! over real loopback TCP.
//!
//! The engine-backed tests require `make artifacts` and skip silently
//! otherwise (same idiom as `cluster_serving.rs`); the membership
//! protocol test is engine-free and always runs. Worker nodes run as
//! in-process threads here — the RPC path is identical to separate
//! processes (real sockets, real wire encoding); true multi-process mode
//! is exercised by `examples/dist_bench.rs --procs` and ci.sh.

use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, ModelConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, RpcClient, SubmitWire, WorkerNode};
use instgenie::engine::request::{EditError, EditRequestBuilder};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::json::Json;
use instgenie::workload::{MaskDist, TraceGen};

const MODEL: &str = "sd21m";

fn engine() -> EngineConfig {
    let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
    e.prepost_cpu_us = 200; // keep tests quick
    e
}

/// Launch options for one worker node (None without artifacts).
fn node_opts() -> Option<ClusterOpts> {
    Manifest::load("artifacts").ok()?;
    Some(ClusterOpts {
        workers: 1,
        engine: engine(),
        model: MODEL.into(),
        artifact_dir: "artifacts".into(),
        templates: vec!["tpl-0".into(), "tpl-1".into()],
        lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
        warmup: false,
    })
}

fn make_router(mcfg: ModelConfig, sched_name: &str, cfg: &DistConfig) -> Arc<Router> {
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let e = engine();
    let sched =
        scheduler::by_name(sched_name, &mcfg, &lat, e.cache_mode, e.max_batch).expect("scheduler");
    Router::new(mcfg, sched, None, cfg.clone())
}

/// Router + N worker nodes over loopback TCP, ready to serve.
fn dist_plane(workers: usize, sched_name: &str) -> Option<(Arc<Router>, Vec<Arc<WorkerNode>>)> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model(MODEL).ok()?.config.clone();
    let cfg = DistConfig::fast();
    let router = make_router(mcfg, sched_name, &cfg);
    let addr = router.start("127.0.0.1:0").expect("router start");
    let mut nodes = Vec::new();
    for i in 0..workers {
        let node = Arc::new(WorkerNode::launch(format!("w{i}"), node_opts()?).expect("node"));
        node.start("127.0.0.1:0").expect("node start");
        node.announce_to(&addr.to_string(), &cfg);
        nodes.push(node);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.ready_count() < workers {
        assert!(
            Instant::now() < deadline,
            "workers never became ready at the router"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    Some((router, nodes))
}

#[test]
fn remote_results_are_bit_identical_to_in_process() {
    let Some((router, nodes)) = dist_plane(2, "round-robin") else { return };
    let Some(opts) = node_opts() else { return };
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let mcfg = Manifest::load("artifacts")
        .unwrap()
        .model(MODEL)
        .unwrap()
        .config
        .clone();
    let e = engine();
    let sched =
        scheduler::by_name("round-robin", &mcfg, &lat, e.cache_mode, e.max_batch).unwrap();
    let baseline = Cluster::launch(ClusterOpts { workers: 2, ..opts }, sched).expect("baseline");

    // a Zipf-popular trace over both planes, identical events
    let gen = TraceGen::new(50.0, MaskDist::Production, 2, 7).with_zipf(1.1);
    let events = gen.generate(8);
    let local: Vec<_> = events.iter().map(|ev| baseline.submit_event(ev)).collect();
    let remote: Vec<_> = events
        .iter()
        .map(|ev| router.submit_event(ev).expect("router accepts"))
        .collect();
    for (l, r) in local.iter().zip(&remote) {
        let a = l.wait(Duration::from_secs(120)).expect("local completion");
        let b = r.wait(Duration::from_secs(120)).expect("remote completion");
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.latent.data(),
            b.latent.data(),
            "latents must be bit-identical across the RPC plane"
        );
        assert_eq!(
            a.image.data(),
            b.image.data(),
            "images must be bit-identical across the RPC plane"
        );
        assert_eq!(a.mask_ratio, b.mask_ratio);
    }
    router.shutdown();
    for n in &nodes {
        n.stop();
    }
    baseline.shutdown().expect("baseline shutdown");
}

#[test]
fn killing_a_worker_mid_trace_loses_no_tickets() {
    let Some((router, nodes)) = dist_plane(2, "round-robin") else { return };
    let gen = TraceGen::new(100.0, MaskDist::Production, 2, 11).with_zipf(1.0);
    let events = gen.generate(16);
    let tickets: Vec<_> = events
        .iter()
        .map(|ev| router.submit_event(ev).expect("router accepts"))
        .collect();
    // kill one worker with the trace in flight: heartbeats stop, the
    // failure detector declares it dead, queued work fails over
    nodes[0].stop();

    let mut done = 0usize;
    let mut lost = 0usize;
    for t in &tickets {
        match t.wait(Duration::from_secs(120)) {
            Ok(resp) => {
                assert_eq!(resp.id, t.id(), "failover must preserve identity");
                done += 1;
            }
            Err(EditError::WorkerLost) => lost += 1,
            Err(e) => panic!("ticket {} resolved to unexpected error {e:?}", t.id()),
        }
    }
    assert_eq!(done + lost, tickets.len(), "every ticket must resolve");
    assert!(done > 0, "the surviving worker must complete work");

    // the membership table converges on the death
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, body) = router.route("GET", "/v1/cluster", "");
        let w0_dead = body
            .at("members")
            .as_arr()
            .map(|ms| {
                ms.iter().any(|m| {
                    m.at("name").as_str() == Some("w0")
                        && m.at("state").as_str() == Some("dead")
                })
            })
            .unwrap_or(false);
        if w0_dead {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "failure detector never declared w0 dead"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    router.shutdown();
    nodes[1].stop();
}

#[test]
fn drained_worker_rejects_new_work_and_router_routes_around_it() {
    let Some((router, nodes)) = dist_plane(2, "round-robin") else { return };
    let (status, reply) = router.route("POST", "/v1/drain/w0", "");
    assert_eq!(status, 200);
    assert_eq!(reply.at("state").as_str(), Some("draining"));
    // the drain RPC reaches the worker synchronously
    assert!(!nodes[0].is_accepting(), "drained node must stop accepting");
    assert!(nodes[1].is_accepting());

    // direct submissions at the drained worker get a typed 503
    let hw = nodes[0].cluster().model.latent_hw;
    let req = EditRequestBuilder::new(900)
        .template("tpl-0")
        .prompt_seed(1)
        .synth_mask(hw, 0.2)
        .expect("mask")
        .build()
        .expect("request");
    let wire = SubmitWire::from_request(&req);
    let (st, body) = nodes[0].route("POST", "/rpc/submit", &wire.to_json().to_string());
    assert_eq!(st, 503);
    assert_eq!(body.at("error_kind").as_str(), Some("draining"));

    // the router keeps serving: everything lands on the live member
    let gen = TraceGen::new(100.0, MaskDist::Production, 2, 3).with_zipf(1.2);
    let events = gen.generate(6);
    let tickets: Vec<_> = events
        .iter()
        .map(|ev| router.submit_event(ev).expect("router accepts"))
        .collect();
    for t in &tickets {
        t.wait(Duration::from_secs(120))
            .expect("completion despite a draining member");
    }
    assert_eq!(
        nodes[1].cluster().completed(),
        events.len(),
        "all work must land on the live member"
    );
    assert_eq!(nodes[0].cluster().completed(), 0);

    // membership reports the drain, and heartbeats keep it draining
    let (_, body) = router.route("GET", "/v1/cluster", "");
    let states: Vec<String> = body
        .at("members")
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.at("state").as_str().unwrap_or("?").to_string())
        .collect();
    assert!(states.contains(&"draining".to_string()));
    router.shutdown();
    for n in &nodes {
        n.stop();
    }
}

/// Engine-free: the announce/heartbeat/expire protocol over real HTTP.
/// Runs everywhere (no artifacts needed).
#[test]
fn membership_http_protocol_round_trips() {
    let mcfg = ModelConfig {
        name: "t".into(),
        latent_hw: 8,
        tokens: 64,
        hidden: 64,
        heads: 4,
        blocks: 4,
        steps: 8,
        token_buckets: vec![4, 8, 16, 32],
        paper_analogue: String::new(),
    };
    let lat = LatencyModel::nominal(1e9, 1e8);
    let e = engine();
    let sched =
        scheduler::by_name("round-robin", &mcfg, &lat, e.cache_mode, e.max_batch).unwrap();
    let router = Router::new(mcfg, sched, None, DistConfig::fast());
    let addr = router.start("127.0.0.1:0").expect("router start");
    let mut client = RpcClient::new(addr.to_string(), Duration::from_secs(5));

    let announce = Json::obj(vec![
        ("name", Json::str("phantom")),
        ("rpc_addr", Json::str("127.0.0.1:1")),
        ("templates", Json::arr(vec![Json::str("tpl-0")])),
    ]);
    let (st, body) = client.call("POST", "/rpc/announce", Some(&announce)).unwrap();
    assert_eq!(st, 200);
    assert_eq!(body.at("slot").as_usize(), Some(0));
    assert_eq!(body.at("epoch").as_usize(), Some(1));

    let hb = Json::obj(vec![("name", Json::str("phantom"))]);
    let (st, _) = client.call("POST", "/rpc/heartbeat", Some(&hb)).unwrap();
    assert_eq!(st, 200);
    let (st, body) = client.call("GET", "/v1/cluster", None).unwrap();
    assert_eq!(st, 200);
    let members = body.at("members").as_arr().unwrap();
    assert_eq!(members.len(), 1);
    assert_eq!(members[0].at("state").as_str(), Some("ready"));
    assert_eq!(body.at("ready").as_usize(), Some(1));

    // silence: suspect, then dead (DistConfig::fast is sub-second)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, body) = client.call("GET", "/v1/cluster", None).unwrap();
        if body.at("members").as_arr().unwrap()[0].at("state").as_str() == Some("dead") {
            break;
        }
        assert!(Instant::now() < deadline, "failure detector never fired");
        std::thread::sleep(Duration::from_millis(50));
    }
    // heartbeats from the dead are refused — the worker must re-announce,
    // which bumps the epoch on the same slot
    let (st, _) = client.call("POST", "/rpc/heartbeat", Some(&hb)).unwrap();
    assert_eq!(st, 410, "dead members must re-announce");
    let (st, body) = client.call("POST", "/rpc/announce", Some(&announce)).unwrap();
    assert_eq!(st, 200);
    assert_eq!(body.at("slot").as_usize(), Some(0), "slots are stable");
    assert_eq!(body.at("epoch").as_usize(), Some(2), "epoch bumps on rejoin");
    router.shutdown();
}
