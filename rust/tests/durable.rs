//! Durable control plane units: journal round trips, torn-tail recovery,
//! segment rotation + snapshot compaction, recovered-state serialization,
//! bounded dedupe (property-tested retry window), latent checkpoints, and
//! idempotency keys. Everything here is artifact-free and always runs.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use instgenie::dist::SubmitWire;
use instgenie::durable::{
    self, load_checkpoint, remove_checkpoint, request_checksum, save_checkpoint, BoundedDedupe,
    DurableLog, FsyncPolicy, IdemKeys, Journal, JournalConfig, RecoveredState,
};
use instgenie::qos::Priority;
use instgenie::util::json::Json;
use instgenie::util::rng::Pcg;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ig-durable-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn wire(id: u64) -> SubmitWire {
    SubmitWire {
        id,
        template: "tpl-0".into(),
        masked: vec![0, 3, 7],
        tokens: 64,
        prompt_seed: 42,
        priority: Priority::default(),
        deadline_ms: None,
        session: None,
    }
}

fn cfg(dir: &std::path::Path) -> JournalConfig {
    let mut c = JournalConfig::new(dir);
    c.fsync = FsyncPolicy::Off; // unit tests: no platter guarantees needed
    c
}

#[test]
fn fsync_policy_parse_label_round_trip() {
    for p in [FsyncPolicy::Always, FsyncPolicy::Batched, FsyncPolicy::Off] {
        assert_eq!(FsyncPolicy::parse(p.label()), Some(p));
    }
    assert_eq!(FsyncPolicy::parse("none"), Some(FsyncPolicy::Off));
    assert_eq!(FsyncPolicy::parse("sometimes"), None);
}

#[test]
fn journal_append_replay_round_trip() {
    let dir = tmp_dir("round-trip");
    let recs: Vec<Json> = (0..5)
        .map(|i| durable::rec_req_state(100 + i, if i % 2 == 0 { "done" } else { "failed" }))
        .collect();
    {
        let (mut j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.records.len(), 0);
        assert!(replay.snapshot.is_none());
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(j.append(r).unwrap(), i as u64 + 1);
        }
        assert_eq!(j.last_seq(), 5);
    }
    let (j, replay) = Journal::open(cfg(&dir)).unwrap();
    assert_eq!(j.last_seq(), 5, "reopen must resume the sequence stream");
    assert_eq!(replay.records.len(), 5);
    for (i, (seq, rec)) in replay.records.iter().enumerate() {
        assert_eq!(*seq, i as u64 + 1);
        assert_eq!(rec, &recs[i], "record {i} must survive the round trip");
    }
}

#[test]
fn journal_torn_tail_is_dropped_not_fatal() {
    let dir = tmp_dir("torn-tail");
    {
        let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
        for i in 0..5 {
            j.append(&durable::rec_req_state(i, "done")).unwrap();
        }
    }
    // Tear the newest segment mid-line, as a crash mid-write would.
    let seg = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "wal"))
        .max()
        .unwrap();
    let bytes = std::fs::read(&seg).unwrap();
    assert!(bytes.len() > 10);
    std::fs::write(&seg, &bytes[..bytes.len() - 7]).unwrap();

    let (mut j, replay) = Journal::open(cfg(&dir)).unwrap();
    assert_eq!(replay.records.len(), 4, "the torn record is dropped, intact ones kept");
    assert_eq!(j.last_seq(), 4, "the torn seq is reused");
    // appending after a tear lands in a fresh segment and replays cleanly
    j.append(&durable::rec_req_state(99, "done")).unwrap();
    drop(j);
    let (_, replay) = Journal::open(cfg(&dir)).unwrap();
    assert_eq!(replay.records.len(), 5);
    assert_eq!(replay.records[4].0, 5);
}

#[test]
fn journal_rotation_and_snapshot_compaction() {
    let dir = tmp_dir("compact");
    let mut c = cfg(&dir);
    c.segment_bytes = 96; // rotate roughly every append
    let wal_count = |dir: &std::path::Path| {
        std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".wal"))
            .count()
    };
    let (mut j, _) = Journal::open(c.clone()).unwrap();
    for i in 0..8 {
        j.append(&durable::rec_req_state(i, "done")).unwrap();
    }
    assert!(wal_count(&dir) >= 4, "tiny segment_bytes must rotate segments");

    // compaction: the caller's state becomes the recovery base
    let mut state = RecoveredState::new();
    state.last_seq = j.last_seq();
    state.templates.insert("tpl-0".into(), "ready".into());
    j.snapshot(&state.to_snapshot_json()).unwrap();
    assert_eq!(wal_count(&dir), 1, "compaction must delete covered segments");

    j.append(&durable::rec_req_state(777, "cancelled")).unwrap();
    drop(j);
    let (_, replay) = Journal::open(c).unwrap();
    let snap = replay.snapshot.expect("snapshot must be recovered");
    assert_eq!(replay.snapshot_seq, 8);
    let restored = RecoveredState::from_snapshot_json(&snap);
    assert_eq!(restored.templates.get("tpl-0").map(String::as_str), Some("ready"));
    assert_eq!(replay.records.len(), 1, "only post-snapshot records replay");
    assert_eq!(replay.records[0].0, 9);
}

#[test]
fn recovered_state_folds_records_and_survives_snapshot_json() {
    let mut st = RecoveredState::new();
    let mut seq = 0;
    let mut apply = |st: &mut RecoveredState, rec: Json| {
        seq += 1;
        st.apply(seq, &rec);
    };
    apply(&mut st, durable::rec_member("w0", "127.0.0.1:9001", 0, 1));
    apply(&mut st, durable::rec_member("w1", "127.0.0.1:9002", 1, 1));
    apply(&mut st, durable::rec_req_accepted(&wire(1_000_000), Some("key-a")));
    apply(&mut st, durable::rec_req_placed(1_000_000, 1));
    apply(&mut st, durable::rec_req_state(1_000_000, "running"));
    apply(&mut st, durable::rec_session_open(1, "tpl-0"));
    let mut round = wire(1_000_001);
    round.session = Some(1);
    apply(&mut st, durable::rec_req_accepted(&round, None));
    apply(&mut st, durable::rec_session_round(1, 1_000_001));
    apply(&mut st, durable::rec_template("tpl-9", "registering"));
    apply(&mut st, durable::rec_req_state(1_000_001, "done"));

    assert_eq!(st.last_seq, 10);
    assert_eq!(st.next_request_id, 1_000_002);
    assert_eq!(st.pending_ids(), vec![1_000_000], "terminal requests are not pending");
    assert_eq!(st.idempotency.get("key-a").copied(), Some(1_000_000));
    let s = st.sessions.get(&1).unwrap();
    assert_eq!(s.rounds, 1);
    assert!(s.inflight.is_empty(), "a done round must leave the inflight set");

    let back = RecoveredState::from_snapshot_json(&st.to_snapshot_json());
    assert_eq!(back.last_seq, st.last_seq);
    assert_eq!(back.next_request_id, st.next_request_id);
    assert_eq!(back.next_session_id, st.next_session_id);
    assert_eq!(back.members.len(), 2);
    assert_eq!(back.members[1].name, "w1");
    let r = back.requests.get(&1_000_000).unwrap();
    assert_eq!(r.slot, Some(1));
    assert!(r.running && !r.is_terminal());
    assert_eq!(r.idem.as_deref(), Some("key-a"));
    assert_eq!(
        back.requests.get(&1_000_001).unwrap().terminal.as_deref(),
        Some("done")
    );
    assert_eq!(back.idempotency.get("key-a").copied(), Some(1_000_000));
    assert_eq!(back.templates.get("tpl-9").map(String::as_str), Some("registering"));
    assert_eq!(back.sessions.get(&1).unwrap().rounds, 1);
}

#[test]
fn durable_log_records_tails_and_recovers() {
    let dir = tmp_dir("log");
    {
        let (log, state) = DurableLog::open(cfg(&dir)).unwrap();
        assert_eq!(state.last_seq, 0);
        log.record(durable::rec_req_accepted(&wire(5), None));
        log.record(durable::rec_req_placed(5, 0));
        log.record(durable::rec_req_state(5, "done"));
        assert_eq!(log.last_seq(), 3);
        // standby tail: ring-served records from any covered cursor
        let tail = log.tail(2);
        assert_eq!(tail.at("last_seq").as_f64(), Some(3.0));
        let recs = tail.at("records").as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].at("seq").as_f64(), Some(2.0));
        // a cursor past the end is an empty heartbeat, not an error
        let ahead = log.tail(9);
        assert_eq!(ahead.at("records").as_arr().unwrap().len(), 0);
        log.flush();
    }
    let (log, state) = DurableLog::open(cfg(&dir)).unwrap();
    assert_eq!(state.last_seq, 3, "reopen must fold the journal back");
    assert_eq!(
        state.requests.get(&5).unwrap().terminal.as_deref(),
        Some("done")
    );

    // takeover adoption: sequence stream continues past the adopted state
    let mut adopted = state.clone();
    adopted.last_seq = 40;
    log.adopt_state(&adopted);
    log.record(durable::rec_req_state(6, "failed"));
    assert_eq!(log.last_seq(), 41, "adoption must continue the primary's stream");
}

/// The satellite property test: a dropped-ack retry inside the window —
/// fewer than `cap` newer inserts and within the TTL — always dedupes,
/// while the set itself stays bounded by `cap`.
#[test]
fn bounded_dedupe_retry_inside_window_always_hits() {
    const CAP: usize = 64;
    const TTL_MS: u64 = 10_000;
    let dd = BoundedDedupe::new(CAP, Duration::from_millis(TTL_MS));
    let t0 = Instant::now();
    let mut rng = Pcg::new(97);
    let mut now_ms = 0u64;
    let mut live: Vec<(u64, u64)> = Vec::new(); // newest-last (id, inserted_at_ms)
    let mut next_id = 1u64;
    for _ in 0..4000 {
        now_ms += rng.below(400) as u64;
        let now = t0 + Duration::from_millis(now_ms);
        if !live.is_empty() && rng.f64() < 0.4 {
            // a worker retrying a wire id whose ack was dropped
            let k = live.len() - 1 - rng.below(live.len());
            let (id, at) = live[k];
            if now_ms - at <= TTL_MS {
                assert!(
                    dd.contains_at(id, now),
                    "id {id} inserted {}ms ago (cap window {}, ttl {TTL_MS}ms) must dedupe",
                    now_ms - at,
                    live.len(),
                );
            }
        } else {
            let id = next_id;
            next_id += 1;
            dd.insert_at(id, now);
            live.push((id, now_ms));
            if live.len() > CAP {
                live.remove(0); // older ids may be capacity-evicted
            }
        }
        assert!(dd.len() <= CAP, "dedupe set must stay bounded");
    }
    // explicit TTL expiry at the boundary
    let id = next_id;
    dd.insert_at(id, t0 + Duration::from_millis(now_ms));
    assert!(dd.contains_at(id, t0 + Duration::from_millis(now_ms + TTL_MS)));
    assert!(!dd.contains_at(id, t0 + Duration::from_millis(now_ms + TTL_MS + 1)));
}

#[test]
fn checkpoint_round_trip_and_corruption_rejection() {
    let dir = tmp_dir("ckpt");
    let sum = request_checksum(9, 42, 3, "tpl-0");
    let mut rng = Pcg::new(5);
    let data: Vec<f32> = (0..256).map(|_| rng.f32()).collect();

    save_checkpoint(&dir, 9, 4, sum, &data).unwrap();
    let (step, loaded) = load_checkpoint(&dir, 9, sum, data.len()).expect("valid checkpoint");
    assert_eq!(step, 4);
    assert_eq!(loaded, data, "resume payload must be bit-identical");

    // wrong request identity: rejected AND deleted, so a later load with
    // the right identity cannot resurrect a mismatched file
    assert!(load_checkpoint(&dir, 9, sum ^ 1, data.len()).is_none());
    assert!(load_checkpoint(&dir, 9, sum, data.len()).is_none());

    // wrong shape: rejected
    save_checkpoint(&dir, 9, 4, sum, &data).unwrap();
    assert!(load_checkpoint(&dir, 9, sum, data.len() + 1).is_none());

    // flipped payload byte: checksum rejects
    save_checkpoint(&dir, 9, 4, sum, &data).unwrap();
    let path = durable::checkpoint_path(&dir, 9);
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    assert!(load_checkpoint(&dir, 9, sum, data.len()).is_none());

    // explicit removal (request finished)
    save_checkpoint(&dir, 9, 6, sum, &data).unwrap();
    remove_checkpoint(&dir, 9);
    assert!(load_checkpoint(&dir, 9, sum, data.len()).is_none());
}

#[test]
fn idem_keys_first_write_wins_within_cap() {
    let keys = IdemKeys::new(4);
    keys.put("a", 1);
    keys.put("a", 2);
    assert_eq!(keys.get("a"), Some(1), "first write wins");
    keys.put("b", 3);
    keys.put("c", 4);
    keys.put("d", 5);
    assert_eq!(keys.len(), 4);
    keys.put("e", 6); // evicts the oldest ("a")
    assert_eq!(keys.get("a"), None, "capacity eviction drops the oldest key");
    assert_eq!(keys.get("e"), Some(6));
    assert!(keys.len() <= 4);
}
