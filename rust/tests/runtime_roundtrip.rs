//! Integration: the python-AOT -> rust-PJRT round trip on real artifacts.
//!
//! Requires `make artifacts` (skipped otherwise). These tests pin the
//! core reuse contract end-to-end through the production load path:
//! HLO text -> PJRT compile -> execute with device-resident weights.

use std::sync::Arc;

use instgenie::model::{Latent, MaskSpec, Permutation};
use instgenie::runtime::{ArtifactKind, Client, Manifest, ModelRuntime};

fn runtime(model: &str) -> Option<ModelRuntime> {
    let manifest = Manifest::load("artifacts").ok()?;
    let client = Arc::new(Client::cpu().expect("PJRT CPU client"));
    Some(ModelRuntime::load(client, &manifest, model).expect("load model"))
}

#[test]
fn block_y_full_matches_registration_block() {
    let Some(rt) = runtime("sd21m") else { return };
    let cfg = &rt.config;
    let x = Latent::noise(cfg.tokens, cfg.hidden, 7, 1.0);
    let (y_reg, _, _) = rt.run_block_reg(0, x.data()).expect("reg");
    let y_full = rt
        .run_block_y(0, cfg.tokens, 1, x.data())
        .expect("full block");
    assert_eq!(y_reg.len(), y_full.len());
    let max_diff = y_reg
        .iter()
        .zip(&y_full)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "max diff {max_diff}");
}

#[test]
fn block_kv_with_exact_cache_matches_full_rows() {
    // Paper Fig. 7 contract through the production path: compute the full
    // block once (registration), then run the cache-KV block over a
    // masked-first compute set with the cached K/V of the other rows —
    // outputs must match the corresponding rows of the full output.
    let Some(rt) = runtime("sd21m") else { return };
    let cfg = rt.config.clone();
    let mut rng = instgenie::util::rng::Pcg::new(3);
    let mask = MaskSpec::synth(cfg.latent_hw, 0.2, &mut rng);
    let perm = Permutation::masked_first(&mask);
    let n = cfg.bucket_for(perm.masked_count());

    let x = Latent::noise(cfg.tokens, cfg.hidden, 11, 1.0);
    let (y_full, k_full, v_full) = rt.run_block_reg(1, x.data()).expect("reg");

    // gather compute rows of x and cached rows of k/v per the permutation
    let h = cfg.hidden;
    let mut x_m = vec![0.0f32; n * h];
    x.gather_into(perm.compute_ids(n), &mut x_m);
    let gather = |src: &[f32], ids: &[usize]| {
        let mut out = vec![0.0f32; ids.len() * h];
        for (i, &id) in ids.iter().enumerate() {
            out[i * h..(i + 1) * h].copy_from_slice(&src[id * h..(id + 1) * h]);
        }
        out
    };
    let kc = gather(&k_full, perm.cached_ids(n));
    let vc = gather(&v_full, perm.cached_ids(n));

    let y_m = rt
        .run_block_kv(1, n, 1, &x_m, &kc, &vc)
        .expect("kv block");

    let y_want = gather(&y_full, perm.compute_ids(n));
    let max_diff = y_m
        .iter()
        .zip(&y_want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "max diff {max_diff}");
}

#[test]
fn batched_execution_is_member_independent() {
    // A batch of 2 identical members must produce identical outputs, and
    // each must equal the batch-1 result (continuous batching relies on
    // member independence inside a batch).
    let Some(rt) = runtime("sd21m") else { return };
    let cfg = &rt.config;
    let n = cfg.token_buckets[2];
    let h = cfg.hidden;
    let x1 = Latent::noise(n, h, 5, 1.0);
    let single = rt.run_block_y(0, n, 1, x1.data()).expect("b1");
    let mut x2 = x1.data().to_vec();
    x2.extend_from_slice(x1.data());
    let pair = rt.run_block_y(0, n, 2, &x2).expect("b2");
    assert_eq!(pair.len(), 2 * single.len());
    for (i, want) in single.iter().enumerate() {
        assert!((pair[i] - want).abs() < 1e-4, "member 0 row {i}");
        assert!((pair[single.len() + i] - want).abs() < 1e-4, "member 1 row {i}");
    }
}

#[test]
fn warmup_compiles_grid() {
    let Some(rt) = runtime("sd21m") else { return };
    rt.warmup(&[1, 2]).expect("warmup");
    assert!(rt.client().compiled_count() >= 2 * (5 + 4) + 1);
}

#[test]
fn device_chain_matches_host_roundtrip_bitwise() {
    // The device-resident invariant at the runtime layer: chaining block
    // output buffers device-to-device equals the per-block host round
    // trip bit-for-bit (gather/scatter identity, same programs).
    let Some(rt) = runtime("sd21m") else { return };
    let cfg = rt.config.clone();
    let n = cfg.token_buckets[1];
    if !rt.device_chain_supported(ArtifactKind::BlockY, n, 1) {
        return; // pre-v4 tuple-root artifacts: chain unavailable
    }
    let x = Latent::noise(n, cfg.hidden, 3, 1.0);
    let mut host = x.data().to_vec();
    for blk in 0..cfg.blocks {
        host = rt.run_block_y(blk, n, 1, &host).expect("host block");
    }
    let mut buf = rt.upload(x.data(), &[1, n, cfg.hidden]).expect("upload");
    for blk in 0..cfg.blocks {
        buf = rt.run_block_y_dev(blk, n, 1, &buf).expect("dev block");
    }
    let mut dev = Vec::new();
    rt.fetch_block_output(ArtifactKind::BlockY, n, 1, &buf, &mut dev)
        .expect("fetch");
    assert_eq!(host.len(), dev.len());
    for (i, (a, b)) in host.iter().zip(&dev).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row {i} diverged");
    }
}

#[test]
fn transfer_counters_count_step_traffic_only() {
    let Some(rt) = runtime("sd21m") else { return };
    let cfg = rt.config.clone();
    let n = cfg.token_buckets[0];
    let h = cfg.hidden;
    let x = Latent::noise(n, h, 9, 1.0);
    let t0 = rt.transfer_totals();
    assert_eq!(t0.h2d_ops, 0, "weights/test uploads are uncounted");

    // host call: one upload + one download
    rt.run_block_y(0, n, 1, x.data()).expect("host block");
    let t1 = rt.transfer_totals();
    assert_eq!((t1.h2d_ops - t0.h2d_ops, t1.d2h_ops - t0.d2h_ops), (1, 1));
    assert_eq!(t1.h2d_bytes - t0.h2d_bytes, (n * h * 4) as u64);

    if !rt.device_chain_supported(ArtifactKind::BlockY, n, 1) {
        return;
    }
    // device chain over every block: one upload + one download total
    let mut buf = rt
        .upload_activations(x.data(), &[1, n, h])
        .expect("upload");
    for blk in 0..cfg.blocks {
        buf = rt.run_block_y_dev(blk, n, 1, &buf).expect("dev block");
    }
    let mut out = Vec::new();
    rt.fetch_block_output(ArtifactKind::BlockY, n, 1, &buf, &mut out)
        .expect("fetch");
    let t2 = rt.transfer_totals();
    assert_eq!(
        (t2.h2d_ops - t1.h2d_ops, t2.d2h_ops - t1.d2h_ops),
        (1, 1),
        "a {}-block chain still costs exactly 2 transfers",
        cfg.blocks
    );
}

#[test]
fn load_hlo_compiles_once_per_key() {
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let man = manifest.model("sd21m").expect("model");
    let art = &man.artifacts[0];
    let client = Client::cpu().expect("client");
    let a = client.load_hlo(&art.name, &art.file).expect("compile");
    let before = client.compiled_count();
    let b = client.load_hlo(&art.name, &art.file).expect("cached");
    assert!(Arc::ptr_eq(&a, &b), "second load must reuse the executable");
    assert_eq!(client.compiled_count(), before);
}

#[test]
fn all_models_load_and_execute() {
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let client = Arc::new(Client::cpu().expect("client"));
    for name in ["sd21m", "sdxlm", "fluxm"] {
        let rt = ModelRuntime::load(Arc::clone(&client), &manifest, name).expect("load");
        let cfg = &rt.config;
        let n = cfg.token_buckets[0];
        let x = Latent::noise(n, cfg.hidden, 1, 1.0);
        let y = rt.run_block_y(cfg.blocks - 1, n, 1, x.data()).expect("exec");
        assert_eq!(y.len(), n * cfg.hidden);
        assert!(y.iter().all(|v| v.is_finite()), "{name} produced non-finite");
    }
}
