//! Integration: full cluster serving across systems, policies and
//! schedulers (requires `make artifacts`; tests skip silently otherwise).

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
use instgenie::metrics::Recorder;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::workload::{MaskDist, TraceGen};

fn launch(system: SystemKind, workers: usize, sched_name: &str) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model("sd21m").ok()?.config.clone();
    let mut engine = EngineConfig::for_system(system);
    engine.prepost_cpu_us = 200; // keep tests quick
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name(sched_name, &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    Some(
        Cluster::launch(
            ClusterOpts {
                workers,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into(), "tpl-1".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .expect("launch"),
    )
}

fn run_trace(cluster: &Cluster, rps: f64, count: usize) {
    let gen = TraceGen::new(rps, MaskDist::Production, 2, 7);
    let events = gen.generate(count);
    instgenie::workload::replay(&events, |ev| {
        cluster.submit_event(ev);
    });
    assert!(
        cluster.await_completed(count, Duration::from_secs(120)),
        "timed out waiting for {count} responses"
    );
}

#[test]
fn instgenie_cluster_serves_all_requests() {
    let Some(cluster) = launch(SystemKind::InstGenIE, 2, "mask-aware") else { return };
    run_trace(&cluster, 8.0, 16);
    let responses = cluster.shutdown().expect("shutdown");
    assert_eq!(responses.len(), 16);
    let mut rec = Recorder::new();
    for r in &responses {
        assert!(r.image.data().iter().all(|v| v.is_finite()));
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
        assert_eq!(r.timing.steps_computed, 8); // sd21m steps
        rec.record(r);
    }
    let rep = rec.report(1.0);
    assert!(rep.e2e.mean > 0.0 && rep.queue.mean >= 0.0);
    // disaggregated continuous batching: the engine thread is never
    // interrupted by pre/post processing
    assert_eq!(rep.mean_interruptions, 0.0);
}

#[test]
fn all_baseline_systems_complete() {
    for system in [SystemKind::Diffusers, SystemKind::FisEdit, SystemKind::TeaCache] {
        let Some(cluster) = launch(system, 1, "request-lb") else { return };
        run_trace(&cluster, 8.0, 6);
        let responses = cluster.shutdown().expect("shutdown");
        assert_eq!(responses.len(), 6, "{system:?}");
        if system == SystemKind::TeaCache {
            // TeaCache must actually skip some steps
            let skipped = responses
                .iter()
                .any(|r| r.timing.steps_computed < 8);
            assert!(skipped, "teacache never skipped");
        }
    }
}

#[test]
fn continuous_beats_static_on_queueing() {
    // burst of requests at one worker: static batching forces the burst
    // tail to wait for whole-batch completion; continuous joins per step.
    let run = |policy: BatchingPolicy| {
        let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
        engine.batching = policy;
        engine.max_batch = 4;
        engine.prepost_cpu_us = 100;
        let manifest = Manifest::load("artifacts").unwrap();
        let mcfg = manifest.model("sd21m").unwrap().config.clone();
        let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
        let sched =
            scheduler::by_name("request-lb", &mcfg, &lat, engine.cache_mode, 4).unwrap();
        let cluster = Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into()],
                lat_model: lat,
                warmup: true, // latency comparison: exclude compile jitter
            },
            sched,
        )
        .unwrap();
        run_trace(&cluster, 30.0, 12);
        let responses = cluster.shutdown().unwrap();
        let mut rec = Recorder::new();
        for r in &responses {
            rec.record(r);
        }
        rec.report(1.0).queue.mean
    };
    let q_static = run(BatchingPolicy::Static);
    let q_cont = run(BatchingPolicy::ContinuousDisaggregated);
    assert!(
        q_cont < q_static,
        "continuous queuing {q_cont:.4}s !< static {q_static:.4}s"
    );
}

#[test]
fn strawman_inline_batching_interrupts_requests() {
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let mcfg = manifest.model("sd21m").unwrap().config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.batching = BatchingPolicy::ContinuousInline;
    engine.prepost_cpu_us = 100;
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name("request-lb", &mcfg, &lat, engine.cache_mode, 8).unwrap();
    let cluster = Cluster::launch(
        ClusterOpts {
            workers: 1,
            engine,
            model: "sd21m".into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into()],
            lat_model: lat,
            warmup: false,
        },
        sched,
    )
    .unwrap();
    run_trace(&cluster, 20.0, 10);
    let responses = cluster.shutdown().unwrap();
    let total_intr: u32 = responses.iter().map(|r| r.timing.interruptions).sum();
    assert!(total_intr > 0, "inline pre/post never interrupted the batch");
}

#[test]
fn schedulers_all_route_and_complete() {
    for sched_name in ["round-robin", "request-lb", "token-lb", "mask-aware"] {
        let Some(cluster) = launch(SystemKind::InstGenIE, 3, sched_name) else { return };
        run_trace(&cluster, 16.0, 12);
        let responses = cluster.shutdown().expect("shutdown");
        assert_eq!(responses.len(), 12, "{sched_name}");
    }
}
