//! Integration: full cluster serving across systems, policies and
//! schedulers (requires `make artifacts`; tests skip silently otherwise),
//! plus the handle-based lifecycle — per-request tickets, typed errors,
//! and queued-request cancellation.

use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{CancelOutcome, Cluster, ClusterOpts};
use instgenie::config::{BatchingPolicy, CacheMode, EngineConfig, SystemKind};
use instgenie::engine::request::{EditError, EditRequestBuilder};
use instgenie::metrics::Recorder;
use instgenie::model::MaskSpec;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::util::rng::Pcg;
use instgenie::workload::{MaskDist, TraceGen};

fn launch(system: SystemKind, workers: usize, sched_name: &str) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model("sd21m").ok()?.config.clone();
    let mut engine = EngineConfig::for_system(system);
    engine.prepost_cpu_us = 200; // keep tests quick
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name(sched_name, &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    Some(
        Cluster::launch(
            ClusterOpts {
                workers,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into(), "tpl-1".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .expect("launch"),
    )
}

fn run_trace(cluster: &Cluster, rps: f64, count: usize) {
    let gen = TraceGen::new(rps, MaskDist::Production, 2, 7);
    let events = gen.generate(count);
    instgenie::workload::replay(&events, |ev| {
        cluster.submit_event(ev);
    });
    assert!(
        cluster.await_completed(count, Duration::from_secs(120)),
        "timed out waiting for {count} responses"
    );
}

#[test]
fn tickets_resolve_to_their_own_responses() {
    let Some(cluster) = launch(SystemKind::InstGenIE, 2, "mask-aware") else { return };
    let hw = cluster.model.latent_hw;
    let mut rng = Pcg::new(11);
    let tickets: Vec<_> = (0..6u64)
        .map(|i| {
            let req = EditRequestBuilder::new(i)
                .template(if i % 2 == 0 { "tpl-0" } else { "tpl-1" })
                .prompt_seed(100 + i)
                .mask(MaskSpec::synth(hw, 0.12, &mut rng))
                .build()
                .expect("valid request");
            cluster.submit_checked(req).expect("known template")
        })
        .collect();
    for (i, t) in tickets.iter().enumerate() {
        let resp = t.wait(Duration::from_secs(120)).expect("completion");
        assert_eq!(resp.id, i as u64, "ticket must resolve to its own result");
        assert_eq!(t.id(), i as u64);
        assert!(resp.timing.e2e > 0.0);
        // terminal states are retained: waiting again returns the same
        assert_eq!(t.wait(Duration::from_millis(1)).unwrap().id, i as u64);
        assert_eq!(t.status().unwrap().state.label(), "done");
    }
    // unknown templates are rejected before reaching a worker queue
    let req = EditRequestBuilder::new(99)
        .template("no-such-template")
        .mask(MaskSpec::synth(hw, 0.1, &mut rng))
        .build()
        .unwrap();
    assert!(matches!(
        cluster.submit_checked(req),
        Err(EditError::UnknownTemplate(_))
    ));
    cluster.shutdown().expect("shutdown");
}

#[test]
fn cancel_queued_request_yields_cancelled() {
    // inline batching with batch=1: later submissions stay in the raw
    // queue while the first request runs -> deterministic cancel window
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let mcfg = manifest.model("sd21m").unwrap().config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.batching = BatchingPolicy::ContinuousInline;
    engine.max_batch = 1;
    engine.prepost_cpu_us = 100;
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name("request-lb", &mcfg, &lat, engine.cache_mode, 1).unwrap();
    let cluster = Cluster::launch(
        ClusterOpts {
            workers: 1,
            engine,
            model: "sd21m".into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into()],
            lat_model: lat,
            warmup: false,
        },
        sched,
    )
    .unwrap();
    let hw = cluster.model.latent_hw;
    let mut rng = Pcg::new(5);
    let tickets: Vec<_> = (0..4u64)
        .map(|i| {
            let req = EditRequestBuilder::new(i)
                .template("tpl-0")
                .prompt_seed(i)
                .mask(MaskSpec::synth(hw, 0.1, &mut rng))
                .build()
                .unwrap();
            cluster.submit(req)
        })
        .collect();
    // the last request cannot have been admitted yet (batch=1, FIFO)
    let victim = tickets.last().unwrap();
    assert_eq!(cluster.cancel(victim.id()), CancelOutcome::Cancelled);
    assert!(matches!(
        victim.wait(Duration::from_secs(1)),
        Err(EditError::Cancelled)
    ));
    assert_eq!(victim.status().unwrap().state.label(), "cancelled");
    // double-cancel and unknown ids are distinct outcomes
    assert_eq!(cluster.cancel(victim.id()), CancelOutcome::TooLate);
    assert_eq!(cluster.cancel(424242), CancelOutcome::NotFound);
    // the survivors complete untouched; cancellation retired the book
    // entry, so the collector's accounting still drains cleanly
    for t in &tickets[..3] {
        assert_eq!(
            t.wait(Duration::from_secs(120)).expect("survivor").id,
            t.id()
        );
    }
    assert!(cluster.queue_depths().iter().all(|d| d.outstanding == 0));
    let responses = cluster.shutdown().expect("shutdown");
    assert_eq!(responses.len(), 3);
}

#[test]
fn instgenie_cluster_serves_all_requests() {
    let Some(cluster) = launch(SystemKind::InstGenIE, 2, "mask-aware") else { return };
    run_trace(&cluster, 8.0, 16);
    let responses = cluster.shutdown().expect("shutdown");
    assert_eq!(responses.len(), 16);
    let mut rec = Recorder::new();
    for r in &responses {
        assert!(r.image.data().iter().all(|v| v.is_finite()));
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
        assert_eq!(r.timing.steps_computed, 8); // sd21m steps
        rec.record(r);
    }
    let rep = rec.report(1.0);
    assert!(rep.e2e.mean > 0.0 && rep.queue.mean >= 0.0);
    // disaggregated continuous batching: the engine thread is never
    // interrupted by pre/post processing
    assert_eq!(rep.mean_interruptions, 0.0);
}

#[test]
fn all_baseline_systems_complete() {
    for system in [SystemKind::Diffusers, SystemKind::FisEdit, SystemKind::TeaCache] {
        let Some(cluster) = launch(system, 1, "request-lb") else { return };
        run_trace(&cluster, 8.0, 6);
        let responses = cluster.shutdown().expect("shutdown");
        assert_eq!(responses.len(), 6, "{system:?}");
        if system == SystemKind::TeaCache {
            // TeaCache must actually skip some steps
            let skipped = responses
                .iter()
                .any(|r| r.timing.steps_computed < 8);
            assert!(skipped, "teacache never skipped");
        }
    }
}

#[test]
fn continuous_beats_static_on_queueing() {
    // burst of requests at one worker: static batching forces the burst
    // tail to wait for whole-batch completion; continuous joins per step.
    let run = |policy: BatchingPolicy| {
        let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
        engine.batching = policy;
        engine.max_batch = 4;
        engine.prepost_cpu_us = 100;
        let manifest = Manifest::load("artifacts").unwrap();
        let mcfg = manifest.model("sd21m").unwrap().config.clone();
        let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
        let sched =
            scheduler::by_name("request-lb", &mcfg, &lat, engine.cache_mode, 4).unwrap();
        let cluster = Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into()],
                lat_model: lat,
                warmup: true, // latency comparison: exclude compile jitter
            },
            sched,
        )
        .unwrap();
        run_trace(&cluster, 30.0, 12);
        let responses = cluster.shutdown().unwrap();
        let mut rec = Recorder::new();
        for r in &responses {
            rec.record(r);
        }
        rec.report(1.0).queue.mean
    };
    let q_static = run(BatchingPolicy::Static);
    let q_cont = run(BatchingPolicy::ContinuousDisaggregated);
    assert!(
        q_cont < q_static,
        "continuous queuing {q_cont:.4}s !< static {q_static:.4}s"
    );
}

#[test]
fn strawman_inline_batching_interrupts_requests() {
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let mcfg = manifest.model("sd21m").unwrap().config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.batching = BatchingPolicy::ContinuousInline;
    engine.prepost_cpu_us = 100;
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name("request-lb", &mcfg, &lat, engine.cache_mode, 8).unwrap();
    let cluster = Cluster::launch(
        ClusterOpts {
            workers: 1,
            engine,
            model: "sd21m".into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into()],
            lat_model: lat,
            warmup: false,
        },
        sched,
    )
    .unwrap();
    run_trace(&cluster, 20.0, 10);
    let responses = cluster.shutdown().unwrap();
    let total_intr: u32 = responses.iter().map(|r| r.timing.interruptions).sum();
    assert!(total_intr > 0, "inline pre/post never interrupted the batch");
}

#[test]
fn schedulers_all_route_and_complete() {
    for sched_name in ["round-robin", "request-lb", "token-lb", "mask-aware"] {
        let Some(cluster) = launch(SystemKind::InstGenIE, 3, sched_name) else { return };
        run_trace(&cluster, 16.0, 12);
        let responses = cluster.shutdown().expect("shutdown");
        assert_eq!(responses.len(), 12, "{sched_name}");
    }
}
