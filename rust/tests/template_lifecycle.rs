//! Integration: the online template lifecycle (requires `make artifacts`;
//! tests skip silently otherwise) — register-while-serving, the
//! submit-during-registration park/timeout races, retire-while-edits-
//! inflight draining, tier purges, and re-registration after delete.

use std::time::{Duration, Instant};

use instgenie::cache::tier::Residency;
use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::engine::request::{EditError, EditRequestBuilder};
use instgenie::model::MaskSpec;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::templates::{RegisterAdmission, RetireOutcome, TemplateState};
use instgenie::util::rng::Pcg;

fn launch(workers: usize, tweak: impl FnOnce(&mut EngineConfig)) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model("sd21m").ok()?.config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 200; // keep tests quick
    tweak(&mut engine);
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name(
        "cache-aware",
        &mcfg,
        &lat,
        engine.cache_mode,
        engine.max_batch,
    )
    .expect("scheduler");
    Some(
        Cluster::launch(
            ClusterOpts {
                workers,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .expect("launch"),
    )
}

fn edit(cluster: &Cluster, id: u64, template: &str, rng: &mut Pcg) -> instgenie::engine::request::EditRequest {
    let hw = cluster.model.latent_hw;
    EditRequestBuilder::new(id)
        .template(template)
        .prompt_seed(id)
        .mask(MaskSpec::synth(hw, 0.12, rng))
        .build()
        .expect("valid request")
}

#[test]
fn register_online_while_serving_then_edit() {
    let Some(cluster) = launch(2, |_| {}) else { return };
    let mut rng = Pcg::new(3);

    // duplicate launch registration is deduped (satellite: no re-trace)
    assert_eq!(
        cluster.register_template_async("tpl-0"),
        RegisterAdmission::AlreadyReady
    );

    // a brand-new template registers in the background while serving
    let adm = cluster.register_template_async("tpl-online");
    assert!(matches!(adm, RegisterAdmission::Started { .. }));
    // submissions during registration are accepted and queue at the worker
    let during = cluster
        .submit_checked(edit(&cluster, 1, "tpl-online", &mut rng))
        .expect("registering templates accept submissions");
    // registration publishes into *every* worker tier
    cluster
        .await_template("tpl-online", Duration::from_secs(120))
        .expect("registration completes");
    let status = cluster.template_status("tpl-online").expect("known");
    assert_eq!(status.info.state, TemplateState::Ready);
    assert!(status.info.bytes > 0);
    assert_eq!(status.residency.len(), 2);
    assert!(
        status.residency.iter().all(|r| *r == Residency::Host),
        "registration must fan into every worker tier: {:?}",
        status.residency
    );
    // the queued-during-registration edit completes without restart
    let resp = during.wait(Duration::from_secs(120)).expect("parked edit served");
    assert_eq!(resp.template_id, "tpl-online");
    // and a fresh post-registration edit also serves
    let after = cluster
        .submit_checked(edit(&cluster, 2, "tpl-online", &mut rng))
        .expect("ready template");
    assert_eq!(after.wait(Duration::from_secs(120)).expect("served").id, 2);
    cluster.shutdown().expect("shutdown");
}

#[test]
fn submit_during_stuck_registration_times_out() {
    // begin a registration directly on the registry WITHOUT enqueueing a
    // trace job: the template stays `registering` forever, so the parked
    // request must resolve via the worker's registration-wait deadline.
    let Some(cluster) = launch(1, |e| e.registration_wait_ms = 150) else { return };
    let mut rng = Pcg::new(4);
    assert!(matches!(
        cluster.template_registry().begin_register("tpl-stuck"),
        RegisterAdmission::Started { .. }
    ));
    let t = cluster
        .submit_checked(edit(&cluster, 10, "tpl-stuck", &mut rng))
        .expect("registering templates accept submissions");
    let t0 = Instant::now();
    let err = t.wait(Duration::from_secs(30)).expect_err("must time out");
    assert_eq!(err, EditError::Timeout);
    assert!(
        t0.elapsed() >= Duration::from_millis(100),
        "timed out before the registration wait elapsed"
    );
    // the cluster still serves other templates afterwards
    let ok = cluster
        .submit_checked(edit(&cluster, 11, "tpl-0", &mut rng))
        .expect("known template");
    assert_eq!(ok.wait(Duration::from_secs(120)).expect("served").id, 11);
    cluster.shutdown().expect("shutdown");
}

#[test]
fn retire_drains_inflight_edits_and_frees_every_tier() {
    let Some(cluster) = launch(2, |_| {}) else { return };
    let mut rng = Pcg::new(5);
    let tickets: Vec<_> = (0..6u64)
        .map(|i| {
            cluster
                .submit_checked(edit(&cluster, i, "tpl-0", &mut rng))
                .expect("known template")
        })
        .collect();

    // retire while those edits are in flight: either an immediate purge
    // (all already finished) or a drain
    let outcome = cluster.retire_template("tpl-0");
    assert!(
        matches!(outcome, RetireOutcome::Retired | RetireOutcome::Draining { .. }),
        "{outcome:?}"
    );
    // new submissions are rejected with the typed error immediately
    let refused = cluster.submit_checked(edit(&cluster, 99, "tpl-0", &mut rng));
    assert!(matches!(refused, Err(EditError::TemplateRetired(_))));

    // in-flight edits drain: each resolves to its own response, or to the
    // typed retirement error if it was still queued at the worker
    for t in &tickets {
        match t.wait(Duration::from_secs(120)) {
            Ok(resp) => assert_eq!(resp.id, t.id()),
            Err(EditError::TemplateRetired(_)) => {}
            Err(e) => panic!("unexpected drain outcome: {e}"),
        }
    }
    // the drain purge races the last ticket resolution by a hair
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let status = cluster.template_status("tpl-0").expect("entry retained");
        assert_eq!(status.info.state, TemplateState::Retired);
        if status.residency.iter().all(|r| *r == Residency::Absent) {
            break;
        }
        assert!(Instant::now() < deadline, "tiers never purged: {status:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    // host-tier bytes are freed on every worker
    for cache in cluster.cache_stats() {
        assert_eq!(
            cache.host_bytes, 0,
            "worker {} still holds retired bytes",
            cache.worker
        );
        assert_eq!(cache.host_templates, 0);
    }

    // re-register after delete: a fresh epoch, served again end-to-end
    assert!(matches!(
        cluster.register_template_async("tpl-0"),
        RegisterAdmission::Started { .. }
    ));
    cluster
        .await_template("tpl-0", Duration::from_secs(120))
        .expect("re-registration completes");
    let revived = cluster
        .submit_checked(edit(&cluster, 100, "tpl-0", &mut rng))
        .expect("re-registered template");
    assert_eq!(
        revived.wait(Duration::from_secs(120)).expect("served").id,
        100
    );
    let status = cluster.template_status("tpl-0").expect("known");
    assert_eq!(status.info.state, TemplateState::Ready);
    assert!(status.info.epoch >= 2, "re-registration must bump the epoch");
    cluster.shutdown().expect("shutdown");
}

#[test]
fn retire_unknown_template_reports_not_found() {
    let Some(cluster) = launch(1, |_| {}) else { return };
    assert_eq!(cluster.retire_template("ghost"), RetireOutcome::NotFound);
    assert!(matches!(
        cluster.submit_checked(edit(&cluster, 1, "ghost", &mut Pcg::new(1))),
        Err(EditError::UnknownTemplate(_))
    ));
    cluster.shutdown().expect("shutdown");
}
