//! Chaos: seeded fault injection across the serving stack.
//!
//! Every scenario drives real requests through a plane with a
//! deterministic [`FaultPlan`] attached and asserts the robustness
//! invariants: **no hung tickets** (every wait resolves inside its
//! timeout), **no lost or duplicated requests**, and — because the
//! degradation ladder ends at deterministic full-model recompute —
//! **bit-identical latents** to a fault-free baseline for solo requests.
//!
//! Engine-backed scenarios require `make artifacts` and skip silently
//! otherwise (same idiom as `cluster_serving.rs`); the retry-budget
//! scenario is engine-free and always runs.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, ModelConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::faults::{FaultPlan, FaultSite};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::util::json::Json;
use instgenie::workload::{MaskDist, TraceEvent, TraceGen};

const MODEL: &str = "sd21m";
const WAIT: Duration = Duration::from_secs(120);

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ig-chaos-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn engine() -> EngineConfig {
    let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
    e.prepost_cpu_us = 200; // keep tests quick
    e
}

/// One single-worker in-process cluster (None without artifacts).
fn launch(engine: EngineConfig) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model(MODEL).ok()?.config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let sched = scheduler::by_name("round-robin", &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    let opts = ClusterOpts {
        workers: 1,
        engine,
        model: MODEL.into(),
        artifact_dir: "artifacts".into(),
        templates: vec!["tpl-0".into(), "tpl-1".into()],
        lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
        warmup: false,
    };
    Some(Cluster::launch(opts, sched).expect("cluster launch"))
}

/// Run `events` one at a time (solo batches keep the fault-free and
/// faulty runs on identical step schedules, so results must be
/// bit-identical) and return (latent bytes, interruptions) per request.
fn run_solo(cluster: &Cluster, events: &[TraceEvent]) -> Vec<(Vec<f32>, u64)> {
    events
        .iter()
        .map(|ev| {
            let t = cluster.submit_event(ev);
            let resp = t.wait(WAIT).expect("every request must complete");
            assert_eq!(resp.id, t.id());
            (resp.latent.data().to_vec(), resp.timing.interruptions as u64)
        })
        .collect()
}

fn degraded_counts(cluster: &Cluster) -> (u64, u64, u64) {
    let mut disk = 0;
    let mut device = 0;
    let mut loader = 0;
    for s in cluster.worker_snapshots() {
        disk += s.transfers.cache_degraded_disk;
        device += s.transfers.cache_degraded_device;
        loader += s.transfers.cache_degraded_loader;
    }
    (disk, device, loader)
}

/// Disk tier returning corrupted bytes on every read: the per-artifact
/// checksum catches the flip, the ladder demotes to full recompute, the
/// breaker trips after repeated failures — and no request fails.
#[test]
fn corrupt_disk_reads_degrade_to_recompute_with_identical_latents() {
    let mut faulty = engine();
    faulty.host_cache_budget = 1; // force every promotion through disk
    faulty.spill_dir = tmp_dir("corrupt-faulty");
    faulty.faults = Some(FaultPlan::new(7).with_rate(FaultSite::DiskCorrupt, 1.0));
    let mut clean = engine();
    clean.host_cache_budget = 1;
    clean.spill_dir = tmp_dir("corrupt-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 5).generate(5);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    for (i, ((a, _), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: recompute fallback must be bit-identical");
    }

    let (disk, _, _) = degraded_counts(&faulty_cluster);
    assert!(disk > 0, "checksum mismatches must surface as CacheDegraded, got 0");
    assert!(
        faulty_cluster.breaker_trips() >= 1,
        "an always-corrupt disk tier must trip the circuit breaker"
    );

    // the frontend surfaces degradation through readiness, not failures
    let clean_http = HttpServer::new(Arc::new(clean_cluster), 1_000);
    let (st, body) = clean_http.route("GET", "/v1/readyz", "");
    assert_eq!(st, 200, "healthy cluster must be ready: {body}");
    let (st, _) = clean_http.route("GET", "/v1/healthz", "");
    assert_eq!(st, 200);
    faulty_cluster.shutdown().expect("shutdown");
}

/// Loader jobs dropped on the floor: every staged block falls back to
/// the synchronous gather, which is the same deterministic computation.
#[test]
fn dropped_loader_jobs_fall_back_to_synchronous_gather() {
    let mut faulty = engine();
    faulty.spill_dir = tmp_dir("loader-faulty");
    faulty.faults = Some(FaultPlan::new(11).with_rate(FaultSite::LoaderFail, 1.0));
    let mut clean = engine();
    clean.spill_dir = tmp_dir("loader-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 9).generate(3);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    for (i, ((a, _), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: sync-gather fallback must be bit-identical");
    }
    let (_, _, loader) = degraded_counts(&faulty_cluster);
    assert!(loader > 0, "dropped loader jobs must count as CacheDegraded");
    faulty_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Device (HBM) KV uploads that are never retained: every block demotes
/// to per-step re-upload — the device → host rung of the ladder. Pure
/// bandwidth cost; results and request outcomes are untouched.
#[test]
fn kv_upload_failures_demote_to_per_step_reupload() {
    let mut faulty = engine();
    faulty.cache_mode = instgenie::config::CacheMode::CacheKV;
    faulty.spill_dir = tmp_dir("kvup-faulty");
    faulty.faults = Some(FaultPlan::new(15).with_rate(FaultSite::DeviceUpload, 1.0));
    let mut clean = engine();
    clean.cache_mode = instgenie::config::CacheMode::CacheKV;
    clean.spill_dir = tmp_dir("kvup-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 19).generate(3);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    for (i, ((a, _), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: un-retained uploads must not change results");
    }
    let (_, device, _) = degraded_counts(&faulty_cluster);
    assert!(device > 0, "refused device retention must count as CacheDegraded");
    faulty_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Step-boundary worker crashes: in-flight members restart from step 0
/// (reported as interruptions) and still produce the baseline's bits.
#[test]
fn step_boundary_crashes_restart_requests_deterministically() {
    let mut faulty = engine();
    faulty.spill_dir = tmp_dir("crash-faulty");
    faulty.faults = Some(FaultPlan::new(21).with_rate(FaultSite::WorkerCrash, 0.2));
    let mut clean = engine();
    clean.spill_dir = tmp_dir("crash-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 13).generate(4);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    let mut interruptions = 0u64;
    for (i, ((a, ints), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: restarted run must be bit-identical");
        interruptions += ints;
    }
    assert!(
        interruptions > 0,
        "a 20% per-step crash rate over 4 requests must interrupt at least once"
    );
    faulty_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Router + worker nodes over loopback with transport faults on the
/// router's RPC clients: drops, delays and refused connects are absorbed
/// by the budgeted retry — nothing is lost, nothing runs twice.
#[test]
fn transport_faults_lose_no_requests_across_the_dist_plane() {
    let Some(manifest) = Manifest::load("artifacts").ok() else { return };
    let mcfg = manifest.model(MODEL).unwrap().config.clone();
    let mut cfg = DistConfig::fast();
    cfg.faults = Some(
        FaultPlan::new(31)
            .with_rate(FaultSite::RpcDrop, 0.05)
            .with_rate(FaultSite::RpcConnect, 0.05)
            .with_rate(FaultSite::RpcTruncate, 0.03)
            .with_rate(FaultSite::RpcDelay, 0.1),
    );
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let e = engine();
    let sched =
        scheduler::by_name("round-robin", &mcfg, &lat, e.cache_mode, e.max_batch).unwrap();
    let router = Router::new(mcfg, sched, None, cfg.clone());
    let addr = router.start("127.0.0.1:0").expect("router start");

    let mut nodes = Vec::new();
    for i in 0..2 {
        let opts = ClusterOpts {
            workers: 1,
            engine: engine(),
            model: MODEL.into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into(), "tpl-1".into()],
            lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
            warmup: false,
        };
        let node = Arc::new(WorkerNode::launch(format!("w{i}"), opts).expect("node"));
        node.start("127.0.0.1:0").expect("node start");
        node.announce_to(&addr.to_string(), &cfg);
        nodes.push(node);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.ready_count() < 2 {
        assert!(Instant::now() < deadline, "workers never became ready");
        std::thread::sleep(Duration::from_millis(20));
    }

    // worker-local health/readiness while live
    let (st, _) = nodes[0].route("GET", "/v1/healthz", "");
    assert_eq!(st, 200);
    let (st, _) = nodes[0].route("GET", "/v1/readyz", "");
    assert_eq!(st, 200);

    let events = TraceGen::new(100.0, MaskDist::Production, 2, 17).with_zipf(1.1).generate(10);
    let tickets: Vec<_> = events
        .iter()
        .map(|ev| router.submit_event(ev).expect("router accepts through faults"))
        .collect();
    for t in &tickets {
        let resp = t.wait(WAIT).expect("no ticket may hang or fail under transport faults");
        assert_eq!(resp.id, t.id());
    }
    // no duplication: each request completed on exactly one node
    let completed: usize = nodes.iter().map(|n| n.cluster().completed()).sum();
    assert_eq!(completed, events.len(), "lost or duplicated requests");

    // the cluster body exposes the budget spend (may be zero if no
    // submit happened to draw a fault, but the field must exist)
    let (st, body) = router.route("GET", "/v1/cluster", "");
    assert_eq!(st, 200);
    assert!(
        body.at("retry_budget_spent").as_f64().is_some(),
        "cluster body must expose retry_budget_spent: {body}"
    );

    // a drained node flips readiness without dropping liveness
    let (st, _) = router.route("POST", "/v1/drain/w0", "");
    assert_eq!(st, 200);
    let (st, _) = nodes[0].route("GET", "/v1/readyz", "");
    assert_eq!(st, 503, "a draining node must read not-ready");
    let (st, _) = nodes[0].route("GET", "/v1/healthz", "");
    assert_eq!(st, 200, "a draining node is still alive");

    router.shutdown();
    for n in &nodes {
        n.stop();
    }
}

/// Engine-free: a member that never answers drains its retry budget and
/// the router sheds with 429 + Retry-After instead of spinning. Budgets
/// survive re-announces, so a flapping worker cannot refill itself.
#[test]
fn exhausted_retry_budget_surfaces_retry_after() {
    let mcfg = ModelConfig {
        name: "t".into(),
        latent_hw: 8,
        tokens: 64,
        hidden: 64,
        heads: 4,
        blocks: 4,
        steps: 8,
        token_buckets: vec![4, 8, 16, 32],
        paper_analogue: String::new(),
    };
    let lat = LatencyModel::nominal(1e9, 1e8);
    let e = engine();
    let sched =
        scheduler::by_name("round-robin", &mcfg, &lat, e.cache_mode, e.max_batch).unwrap();
    let mut cfg = DistConfig::fast();
    cfg.retry_budget = 1.0;
    cfg.retry_refill_per_sec = 0.01; // one token per 100 s: no refill mid-test
    cfg.retry_attempts = 5;
    let router = Router::new(mcfg, sched, None, cfg);

    // before any member: alive, but not ready
    let (st, _) = router.route("GET", "/v1/healthz", "");
    assert_eq!(st, 200);
    let (st, _) = router.route("GET", "/v1/readyz", "");
    assert_eq!(st, 503, "a routerless-of-members plane is not ready");

    // a phantom member on a port nothing listens on; the heartbeat
    // flips it joining → ready (announce alone leaves it joining)
    let announce = Json::obj(vec![
        ("name", Json::str("phantom")),
        ("rpc_addr", Json::str("127.0.0.1:1")),
        ("templates", Json::arr(vec![Json::str("tpl-0")])),
    ])
    .to_string();
    let beat = r#"{"name":"phantom"}"#;
    let (st, _) = router.route("POST", "/rpc/announce", &announce);
    assert_eq!(st, 200);
    let (st, _) = router.route("POST", "/rpc/heartbeat", beat);
    assert_eq!(st, 200);
    let (st, _) = router.route("GET", "/v1/readyz", "");
    assert_eq!(st, 200, "a ready member makes the router ready");

    // first submission: one real attempt + one budgeted retry, then the
    // single token is gone and the caller is shed with Retry-After
    let body = r#"{"template":"tpl-0","mask_ratio":0.2,"prompt_seed":1}"#;
    let (st, reply) = router.route("POST", "/v1/edits", body);
    assert_eq!(st, 429, "unreachable-member placement must shed: {reply}");
    assert_eq!(reply.at("error_kind").as_str(), Some("overloaded"));
    let after = reply.at("retry_after_ms").as_f64().expect("Retry-After surfaced");
    assert!(after > 0.0, "retry_after_ms must be positive, got {after}");

    // a re-announce (flap) must NOT refill the budget: the next
    // submission is shed immediately, with zero retries spent
    let (st, _) = router.route("POST", "/rpc/announce", &announce);
    assert_eq!(st, 200);
    let (st, _) = router.route("POST", "/rpc/heartbeat", beat);
    assert_eq!(st, 200);
    let (st, reply) = router.route("POST", "/v1/edits", body);
    assert_eq!(st, 429, "budgets must survive re-announces: {reply}");
    let (_, cluster) = router.route("GET", "/v1/cluster", "");
    assert_eq!(
        cluster.at("retry_budget_spent").as_f64(),
        Some(1.0),
        "exactly the one budgeted retry may have been spent: {cluster}"
    );
    router.shutdown();
}
