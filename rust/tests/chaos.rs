//! Chaos: seeded fault injection across the serving stack.
//!
//! Every scenario drives real requests through a plane with a
//! deterministic [`FaultPlan`] attached and asserts the robustness
//! invariants: **no hung tickets** (every wait resolves inside its
//! timeout), **no lost or duplicated requests**, and — because the
//! degradation ladder ends at deterministic full-model recompute —
//! **bit-identical latents** to a fault-free baseline for solo requests.
//!
//! Engine-backed scenarios require `make artifacts` and skip silently
//! otherwise (same idiom as `cluster_serving.rs`); the retry-budget
//! scenario is engine-free and always runs.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts, RequestState};
use instgenie::config::{EngineConfig, ModelConfig, SystemKind};
use instgenie::dist::{DistConfig, Router, WorkerNode};
use instgenie::durable::FsyncPolicy;
use instgenie::faults::{FaultPlan, FaultSite};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::util::json::Json;
use instgenie::workload::{MaskDist, TraceEvent, TraceGen};

const MODEL: &str = "sd21m";
const WAIT: Duration = Duration::from_secs(120);

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ig-chaos-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn engine() -> EngineConfig {
    let mut e = EngineConfig::for_system(SystemKind::InstGenIE);
    e.prepost_cpu_us = 200; // keep tests quick
    e
}

/// One single-worker in-process cluster (None without artifacts).
fn launch(engine: EngineConfig) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model(MODEL).ok()?.config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let sched = scheduler::by_name("round-robin", &mcfg, &lat, engine.cache_mode, engine.max_batch)
        .expect("scheduler");
    let opts = ClusterOpts {
        workers: 1,
        engine,
        model: MODEL.into(),
        artifact_dir: "artifacts".into(),
        templates: vec!["tpl-0".into(), "tpl-1".into()],
        lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
        warmup: false,
    };
    Some(Cluster::launch(opts, sched).expect("cluster launch"))
}

/// Run `events` one at a time (solo batches keep the fault-free and
/// faulty runs on identical step schedules, so results must be
/// bit-identical) and return (latent bytes, interruptions) per request.
fn run_solo(cluster: &Cluster, events: &[TraceEvent]) -> Vec<(Vec<f32>, u64)> {
    events
        .iter()
        .map(|ev| {
            let t = cluster.submit_event(ev);
            let resp = t.wait(WAIT).expect("every request must complete");
            assert_eq!(resp.id, t.id());
            (resp.latent.data().to_vec(), resp.timing.interruptions as u64)
        })
        .collect()
}

fn degraded_counts(cluster: &Cluster) -> (u64, u64, u64) {
    let mut disk = 0;
    let mut device = 0;
    let mut loader = 0;
    for s in cluster.worker_snapshots() {
        disk += s.transfers.cache_degraded_disk;
        device += s.transfers.cache_degraded_device;
        loader += s.transfers.cache_degraded_loader;
    }
    (disk, device, loader)
}

/// Disk tier returning corrupted bytes on every read: the per-artifact
/// checksum catches the flip, the ladder demotes to full recompute, the
/// breaker trips after repeated failures — and no request fails.
#[test]
fn corrupt_disk_reads_degrade_to_recompute_with_identical_latents() {
    let mut faulty = engine();
    faulty.host_cache_budget = 1; // force every promotion through disk
    faulty.spill_dir = tmp_dir("corrupt-faulty");
    faulty.faults = Some(FaultPlan::new(7).with_rate(FaultSite::DiskCorrupt, 1.0));
    let mut clean = engine();
    clean.host_cache_budget = 1;
    clean.spill_dir = tmp_dir("corrupt-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 5).generate(5);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    for (i, ((a, _), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: recompute fallback must be bit-identical");
    }

    let (disk, _, _) = degraded_counts(&faulty_cluster);
    assert!(disk > 0, "checksum mismatches must surface as CacheDegraded, got 0");
    assert!(
        faulty_cluster.breaker_trips() >= 1,
        "an always-corrupt disk tier must trip the circuit breaker"
    );

    // the frontend surfaces degradation through readiness, not failures
    let clean_http = HttpServer::new(Arc::new(clean_cluster), 1_000);
    let (st, body) = clean_http.route("GET", "/v1/readyz", "");
    assert_eq!(st, 200, "healthy cluster must be ready: {body}");
    let (st, _) = clean_http.route("GET", "/v1/healthz", "");
    assert_eq!(st, 200);
    faulty_cluster.shutdown().expect("shutdown");
}

/// Loader jobs dropped on the floor: every staged block falls back to
/// the synchronous gather, which is the same deterministic computation.
#[test]
fn dropped_loader_jobs_fall_back_to_synchronous_gather() {
    let mut faulty = engine();
    faulty.spill_dir = tmp_dir("loader-faulty");
    faulty.faults = Some(FaultPlan::new(11).with_rate(FaultSite::LoaderFail, 1.0));
    let mut clean = engine();
    clean.spill_dir = tmp_dir("loader-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 9).generate(3);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    for (i, ((a, _), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: sync-gather fallback must be bit-identical");
    }
    let (_, _, loader) = degraded_counts(&faulty_cluster);
    assert!(loader > 0, "dropped loader jobs must count as CacheDegraded");
    faulty_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Device (HBM) KV uploads that are never retained: every block demotes
/// to per-step re-upload — the device → host rung of the ladder. Pure
/// bandwidth cost; results and request outcomes are untouched.
#[test]
fn kv_upload_failures_demote_to_per_step_reupload() {
    let mut faulty = engine();
    faulty.cache_mode = instgenie::config::CacheMode::CacheKV;
    faulty.spill_dir = tmp_dir("kvup-faulty");
    faulty.faults = Some(FaultPlan::new(15).with_rate(FaultSite::DeviceUpload, 1.0));
    let mut clean = engine();
    clean.cache_mode = instgenie::config::CacheMode::CacheKV;
    clean.spill_dir = tmp_dir("kvup-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 19).generate(3);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    for (i, ((a, _), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: un-retained uploads must not change results");
    }
    let (_, device, _) = degraded_counts(&faulty_cluster);
    assert!(device > 0, "refused device retention must count as CacheDegraded");
    faulty_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Step-boundary worker crashes: in-flight members restart from step 0
/// (reported as interruptions) and still produce the baseline's bits.
#[test]
fn step_boundary_crashes_restart_requests_deterministically() {
    let mut faulty = engine();
    faulty.spill_dir = tmp_dir("crash-faulty");
    faulty.faults = Some(FaultPlan::new(21).with_rate(FaultSite::WorkerCrash, 0.2));
    let mut clean = engine();
    clean.spill_dir = tmp_dir("crash-clean");

    let Some(faulty_cluster) = launch(faulty) else { return };
    let clean_cluster = launch(clean).expect("baseline");

    let events = TraceGen::new(50.0, MaskDist::Production, 2, 13).generate(4);
    let with_faults = run_solo(&faulty_cluster, &events);
    let baseline = run_solo(&clean_cluster, &events);
    let mut interruptions = 0u64;
    for (i, ((a, ints), (b, _))) in with_faults.iter().zip(&baseline).enumerate() {
        assert_eq!(a, b, "request {i}: restarted run must be bit-identical");
        interruptions += ints;
    }
    assert!(
        interruptions > 0,
        "a 20% per-step crash rate over 4 requests must interrupt at least once"
    );
    faulty_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Router + worker nodes over loopback with transport faults on the
/// router's RPC clients: drops, delays and refused connects are absorbed
/// by the budgeted retry — nothing is lost, nothing runs twice.
#[test]
fn transport_faults_lose_no_requests_across_the_dist_plane() {
    let Some(manifest) = Manifest::load("artifacts").ok() else { return };
    let mcfg = manifest.model(MODEL).unwrap().config.clone();
    let mut cfg = DistConfig::fast();
    cfg.faults = Some(
        FaultPlan::new(31)
            .with_rate(FaultSite::RpcDrop, 0.05)
            .with_rate(FaultSite::RpcConnect, 0.05)
            .with_rate(FaultSite::RpcTruncate, 0.03)
            .with_rate(FaultSite::RpcDelay, 0.1),
    );
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let e = engine();
    let sched =
        scheduler::by_name("round-robin", &mcfg, &lat, e.cache_mode, e.max_batch).unwrap();
    let router = Router::new(mcfg, sched, None, cfg.clone());
    let addr = router.start("127.0.0.1:0").expect("router start");

    let mut nodes = Vec::new();
    for i in 0..2 {
        let opts = ClusterOpts {
            workers: 1,
            engine: engine(),
            model: MODEL.into(),
            artifact_dir: "artifacts".into(),
            templates: vec!["tpl-0".into(), "tpl-1".into()],
            lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
            warmup: false,
        };
        let node = Arc::new(WorkerNode::launch(format!("w{i}"), opts).expect("node"));
        node.start("127.0.0.1:0").expect("node start");
        node.announce_to(&addr.to_string(), &cfg);
        nodes.push(node);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.ready_count() < 2 {
        assert!(Instant::now() < deadline, "workers never became ready");
        std::thread::sleep(Duration::from_millis(20));
    }

    // worker-local health/readiness while live
    let (st, _) = nodes[0].route("GET", "/v1/healthz", "");
    assert_eq!(st, 200);
    let (st, _) = nodes[0].route("GET", "/v1/readyz", "");
    assert_eq!(st, 200);

    let events = TraceGen::new(100.0, MaskDist::Production, 2, 17).with_zipf(1.1).generate(10);
    let tickets: Vec<_> = events
        .iter()
        .map(|ev| router.submit_event(ev).expect("router accepts through faults"))
        .collect();
    for t in &tickets {
        let resp = t.wait(WAIT).expect("no ticket may hang or fail under transport faults");
        assert_eq!(resp.id, t.id());
    }
    // no duplication: each request completed on exactly one node
    let completed: usize = nodes.iter().map(|n| n.cluster().completed()).sum();
    assert_eq!(completed, events.len(), "lost or duplicated requests");

    // the cluster body exposes the budget spend (may be zero if no
    // submit happened to draw a fault, but the field must exist)
    let (st, body) = router.route("GET", "/v1/cluster", "");
    assert_eq!(st, 200);
    assert!(
        body.at("retry_budget_spent").as_f64().is_some(),
        "cluster body must expose retry_budget_spent: {body}"
    );

    // a drained node flips readiness without dropping liveness
    let (st, _) = router.route("POST", "/v1/drain/w0", "");
    assert_eq!(st, 200);
    let (st, _) = nodes[0].route("GET", "/v1/readyz", "");
    assert_eq!(st, 503, "a draining node must read not-ready");
    let (st, _) = nodes[0].route("GET", "/v1/healthz", "");
    assert_eq!(st, 200, "a draining node is still alive");

    router.shutdown();
    for n in &nodes {
        n.stop();
    }
}

/// Engine-free: a member that never answers drains its retry budget and
/// the router sheds with 429 + Retry-After instead of spinning. Budgets
/// survive re-announces, so a flapping worker cannot refill itself.
#[test]
fn exhausted_retry_budget_surfaces_retry_after() {
    let mcfg = ModelConfig {
        name: "t".into(),
        latent_hw: 8,
        tokens: 64,
        hidden: 64,
        heads: 4,
        blocks: 4,
        steps: 8,
        token_buckets: vec![4, 8, 16, 32],
        paper_analogue: String::new(),
    };
    let lat = LatencyModel::nominal(1e9, 1e8);
    let e = engine();
    let sched =
        scheduler::by_name("round-robin", &mcfg, &lat, e.cache_mode, e.max_batch).unwrap();
    let mut cfg = DistConfig::fast();
    cfg.retry_budget = 1.0;
    cfg.retry_refill_per_sec = 0.01; // one token per 100 s: no refill mid-test
    cfg.retry_attempts = 5;
    let router = Router::new(mcfg, sched, None, cfg);

    // before any member: alive, but not ready
    let (st, _) = router.route("GET", "/v1/healthz", "");
    assert_eq!(st, 200);
    let (st, _) = router.route("GET", "/v1/readyz", "");
    assert_eq!(st, 503, "a routerless-of-members plane is not ready");

    // a phantom member on a port nothing listens on; the heartbeat
    // flips it joining → ready (announce alone leaves it joining)
    let announce = Json::obj(vec![
        ("name", Json::str("phantom")),
        ("rpc_addr", Json::str("127.0.0.1:1")),
        ("templates", Json::arr(vec![Json::str("tpl-0")])),
    ])
    .to_string();
    let beat = r#"{"name":"phantom"}"#;
    let (st, _) = router.route("POST", "/rpc/announce", &announce);
    assert_eq!(st, 200);
    let (st, _) = router.route("POST", "/rpc/heartbeat", beat);
    assert_eq!(st, 200);
    let (st, _) = router.route("GET", "/v1/readyz", "");
    assert_eq!(st, 200, "a ready member makes the router ready");

    // first submission: one real attempt + one budgeted retry, then the
    // single token is gone and the caller is shed with Retry-After
    let body = r#"{"template":"tpl-0","mask_ratio":0.2,"prompt_seed":1}"#;
    let (st, reply) = router.route("POST", "/v1/edits", body);
    assert_eq!(st, 429, "unreachable-member placement must shed: {reply}");
    assert_eq!(reply.at("error_kind").as_str(), Some("overloaded"));
    let after = reply.at("retry_after_ms").as_f64().expect("Retry-After surfaced");
    assert!(after > 0.0, "retry_after_ms must be positive, got {after}");

    // a re-announce (flap) must NOT refill the budget: the next
    // submission is shed immediately, with zero retries spent
    let (st, _) = router.route("POST", "/rpc/announce", &announce);
    assert_eq!(st, 200);
    let (st, _) = router.route("POST", "/rpc/heartbeat", beat);
    assert_eq!(st, 200);
    let (st, reply) = router.route("POST", "/v1/edits", body);
    assert_eq!(st, 429, "budgets must survive re-announces: {reply}");
    let (_, cluster) = router.route("GET", "/v1/cluster", "");
    assert_eq!(
        cluster.at("retry_budget_spent").as_f64(),
        Some(1.0),
        "exactly the one budgeted retry may have been spent: {cluster}"
    );
    router.shutdown();
}

// ---------------------------------------------------------------------
// durable control plane: journal replay, checkpoint resume, standby
// ---------------------------------------------------------------------

/// A journal dir that is guaranteed empty (replay is stateful, unlike
/// the content-addressed spill dirs above).
fn fresh_dir(tag: &str) -> PathBuf {
    let d = tmp_dir(tag);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Two single-worker nodes announcing to `routers` (comma-separated
/// failover list). `max_batch = 1` keeps every request in a solo batch
/// so replayed placements stay on the baseline's step schedule.
fn launch_nodes(routers: &str, cfg: &DistConfig) -> Vec<Arc<WorkerNode>> {
    (0..2)
        .map(|i| {
            let mut e = engine();
            e.max_batch = 1;
            let opts = ClusterOpts {
                workers: 1,
                engine: e,
                model: MODEL.into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into(), "tpl-1".into()],
                lat_model: LatencyModel::load_or_nominal("artifacts", MODEL),
                warmup: false,
            };
            let node = Arc::new(WorkerNode::launch(format!("w{i}"), opts).expect("node"));
            node.start("127.0.0.1:0").expect("node start");
            node.announce_to(routers, cfg);
            node
        })
        .collect()
}

/// The Done latent for `id` from whichever router registry holds it
/// (the replayed router for post-crash completions, the halted one for
/// requests that finished before the kill).
fn done_latent(routers: &[&Router], id: u64) -> Option<Vec<f32>> {
    routers.iter().find_map(|r| match r.registry().status(id).map(|s| s.state) {
        Some(RequestState::Done(resp)) => Some(resp.latent.data().to_vec()),
        _ => None,
    })
}

/// kill -9 on the router mid-trace: a fresh router over the same journal
/// replays membership + every accepted request, workers re-announce into
/// their journaled slots, and the pump reconciles in-flight work. Nothing
/// is lost, nothing runs twice, and every latent matches the fault-free
/// baseline bit-for-bit. Idempotency keys survive the crash.
#[test]
fn router_kill_and_journal_replay_loses_nothing() {
    let Some(manifest) = Manifest::load("artifacts").ok() else { return };
    let mcfg = manifest.model(MODEL).unwrap().config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let mut cfg = DistConfig::fast();
    cfg.journal_dir = Some(fresh_dir("journal-replay"));
    cfg.journal_fsync = FsyncPolicy::Always;

    let sched = scheduler::by_name("round-robin", &mcfg, &lat, engine().cache_mode, 1).unwrap();
    let router1 = Router::new(mcfg.clone(), sched, None, cfg.clone());
    let addr1 = router1.start("127.0.0.1:0").expect("router start");
    let nodes = launch_nodes(&addr1.to_string(), &cfg);
    let deadline = Instant::now() + Duration::from_secs(30);
    while router1.ready_count() < 2 {
        assert!(Instant::now() < deadline, "workers never became ready");
        std::thread::sleep(Duration::from_millis(20));
    }

    let events = TraceGen::new(100.0, MaskDist::Production, 2, 23).generate(6);
    let ids: Vec<u64> = events
        .iter()
        .map(|ev| router1.submit_event(ev).expect("accept").id())
        .collect();
    let body = r#"{"template":"tpl-0","mask_ratio":0.2,"prompt_seed":77}"#;
    let (st, reply) = router1.route_with_headers("POST", "/v1/edits", body, Some("retry-1"));
    assert_eq!(st, 202, "idempotent submit accepted: {reply}");
    let idem_id = reply.at("id").as_f64().expect("id") as u64;

    // kill -9 mid-trace: no drain, no flush beyond the per-record appends
    router1.halt_for_test();

    // a fresh process over the same journal
    let sched2 = scheduler::by_name("round-robin", &mcfg, &lat, engine().cache_mode, 1).unwrap();
    let router2 = Router::new(mcfg, sched2, None, cfg.clone());
    let addr2 = router2.start("127.0.0.1:0").expect("replayed router start");
    for n in &nodes {
        n.announce_to(&addr2.to_string(), &cfg);
    }

    // zero lost: every accepted request reaches exactly one terminal
    let total = ids.len() + 1;
    let deadline = Instant::now() + WAIT;
    loop {
        let completed: usize = nodes.iter().map(|n| n.cluster().completed()).sum();
        let all_done = ids
            .iter()
            .chain(std::iter::once(&idem_id))
            .all(|&id| done_latent(&[router2.as_ref(), router1.as_ref()], id).is_some());
        if completed == total && all_done {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "replay lost work: {completed}/{total} completed on workers"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    // zero duplicated: the cumulative per-node count stays put
    std::thread::sleep(Duration::from_millis(300));
    let completed: usize = nodes.iter().map(|n| n.cluster().completed()).sum();
    assert_eq!(completed, total, "replay re-ran already-completed work");

    // the idempotency key survives the crash: a retry replays the ticket
    let (st, reply) = router2.route_with_headers("POST", "/v1/edits", body, Some("retry-1"));
    assert_eq!(st, 202);
    assert_eq!(reply.at("id").as_f64(), Some(idem_id as f64), "key → original id: {reply}");
    assert!(
        matches!(reply.at("idempotent"), Json::Bool(true)),
        "replay must be flagged idempotent: {reply}"
    );

    // bit-identical to a fault-free in-process baseline
    let baseline_cluster = launch(engine()).expect("baseline");
    let baseline = run_solo(&baseline_cluster, &events);
    for (i, id) in ids.iter().enumerate() {
        let latent = done_latent(&[router2.as_ref(), router1.as_ref()], *id).expect("done above");
        assert_eq!(latent, baseline[i].0, "request {i}: replayed run must be bit-identical");
    }

    router2.shutdown();
    for n in &nodes {
        n.stop();
    }
    baseline_cluster.shutdown().expect("shutdown");
}

/// Step-boundary latent checkpoints: under the same seeded crash plan, a
/// checkpointing worker resumes from the last boundary instead of step 0
/// — strictly fewer steps redone — and the final latent still matches the
/// fault-free golden run bit-for-bit. (Seed 23 at rate 0.35 provably
/// crashes this single request several times; the draw sequence is
/// deterministic, so the comparison is exact, not statistical.)
#[test]
fn checkpointed_worker_resumes_and_matches_golden() {
    let mut ckpt = engine();
    ckpt.spill_dir = fresh_dir("ckpt-resume");
    ckpt.checkpoint_every_steps = 2;
    ckpt.faults = Some(FaultPlan::new(23).with_rate(FaultSite::WorkerCrash, 0.35));
    let mut plain = engine();
    plain.spill_dir = fresh_dir("ckpt-plain");
    plain.faults = Some(FaultPlan::new(23).with_rate(FaultSite::WorkerCrash, 0.35));
    let mut clean = engine();
    clean.spill_dir = fresh_dir("ckpt-clean");

    let Some(ckpt_cluster) = launch(ckpt) else { return };
    let plain_cluster = launch(plain).expect("plain");
    let clean_cluster = launch(clean).expect("baseline");

    // exactly one request: both faulty runs then consume the identical
    // crash-draw sequence, which makes the step comparison provable
    let events = TraceGen::new(50.0, MaskDist::Production, 2, 33).generate(1);
    let resumed = run_solo(&ckpt_cluster, &events);
    let restarted = run_solo(&plain_cluster, &events);
    let golden = run_solo(&clean_cluster, &events);

    assert_eq!(resumed[0].0, golden[0].0, "checkpoint resume must be bit-identical");
    assert_eq!(restarted[0].0, golden[0].0, "restart-from-0 must be bit-identical");
    assert!(resumed[0].1 > 0, "the seeded plan must interrupt the checkpointing run");
    assert!(restarted[0].1 > 0, "the seeded plan must interrupt the plain run");

    let s_ckpt: usize =
        ckpt_cluster.worker_snapshots().iter().map(|s| s.steps_executed).sum();
    let s_plain: usize =
        plain_cluster.worker_snapshots().iter().map(|s| s.steps_executed).sum();
    assert!(
        s_ckpt < s_plain,
        "resuming from checkpoints must redo fewer steps ({s_ckpt} vs {s_plain})"
    );

    ckpt_cluster.shutdown().expect("shutdown");
    plain_cluster.shutdown().expect("shutdown");
    clean_cluster.shutdown().expect("shutdown");
}

/// Warm standby: a second router tails the primary's journal, refuses
/// writes while the primary is alive, and promotes itself once the
/// primary goes silent. Workers rotate their announce loop onto the
/// standby, idempotency keys replay across the failover, and the write
/// path works end to end afterwards — with nothing lost or duplicated.
#[test]
fn standby_takes_over_on_primary_silence() {
    let Some(manifest) = Manifest::load("artifacts").ok() else { return };
    let mcfg = manifest.model(MODEL).unwrap().config.clone();
    let lat = LatencyModel::load_or_nominal("artifacts", MODEL);
    let mut pcfg = DistConfig::fast();
    pcfg.journal_dir = Some(fresh_dir("standby-primary"));
    pcfg.journal_fsync = FsyncPolicy::Always;
    let mut scfg = pcfg.clone();
    scfg.journal_dir = Some(fresh_dir("standby-standby"));

    let sched_p = scheduler::by_name("round-robin", &mcfg, &lat, engine().cache_mode, 1).unwrap();
    let primary = Router::new(mcfg.clone(), sched_p, None, pcfg.clone());
    let paddr = primary.start("127.0.0.1:0").expect("primary start");
    let sched_s = scheduler::by_name("round-robin", &mcfg, &lat, engine().cache_mode, 1).unwrap();
    let standby = Router::new(mcfg, sched_s, None, scfg);
    let saddr = standby.start_standby("127.0.0.1:0", &paddr.to_string()).expect("standby start");

    let body = r#"{"template":"tpl-0","mask_ratio":0.2,"prompt_seed":5}"#;
    let (st, reply) = standby.route("POST", "/v1/edits", body);
    assert_eq!(st, 503, "a standby must refuse writes while the primary lives: {reply}");

    // workers get the primary,standby failover list up front
    let nodes = launch_nodes(&format!("{paddr},{saddr}"), &pcfg);
    let deadline = Instant::now() + Duration::from_secs(30);
    while primary.ready_count() < 2 {
        assert!(Instant::now() < deadline, "workers never became ready");
        std::thread::sleep(Duration::from_millis(20));
    }

    let events = TraceGen::new(100.0, MaskDist::Production, 2, 29).generate(5);
    let tickets: Vec<_> = events[..4]
        .iter()
        .map(|ev| primary.submit_event(ev).expect("primary accepts"))
        .collect();
    let (st, reply) = primary.route_with_headers("POST", "/v1/edits", body, Some("sb-1"));
    assert_eq!(st, 202, "{reply}");
    let idem_id = reply.at("id").as_f64().expect("id") as u64;
    for t in &tickets {
        t.wait(WAIT).expect("pre-failover requests complete");
    }
    assert!(primary.await_finished(5, WAIT), "all five terminal before the kill");

    // let the standby's tail catch up past the last record, then kill -9
    std::thread::sleep(Duration::from_millis(1200));
    primary.halt_for_test();

    // silence past the takeover window promotes the standby; the retried
    // idempotency key must replay the original ticket, not mint a new one
    let deadline = Instant::now() + Duration::from_secs(30);
    let reply = loop {
        let (st, reply) = standby.route_with_headers("POST", "/v1/edits", body, Some("sb-1"));
        if st == 202 {
            break reply;
        }
        assert_eq!(st, 503, "pre-takeover the standby still refuses: {reply}");
        assert!(Instant::now() < deadline, "standby never took over");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(
        reply.at("id").as_f64(),
        Some(idem_id as f64),
        "idempotency must survive failover: {reply}"
    );

    // workers rotate their announce loop onto the promoted standby
    let deadline = Instant::now() + Duration::from_secs(30);
    while standby.ready_count() < 2 {
        assert!(Instant::now() < deadline, "workers never re-announced to the standby");
        std::thread::sleep(Duration::from_millis(20));
    }
    // and the write path works end to end after the takeover
    let t = standby.submit_event(&events[4]).expect("standby accepts after takeover");
    t.wait(WAIT).expect("post-failover request completes");

    let completed: usize = nodes.iter().map(|n| n.cluster().completed()).sum();
    assert_eq!(completed, 6, "failover lost or duplicated requests");

    standby.shutdown();
    for n in &nodes {
        n.stop();
    }
}
