//! Integration: the QoS subsystem end to end (requires `make artifacts`;
//! tests skip silently otherwise) — step-boundary preemption resuming
//! bit-identically, deadline expiry while queued, 429/`Retry-After`
//! admission shedding, 422 infeasible deadlines, priority/deadline echo
//! over HTTP, and cancellation reaching parked/preempted requests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{CancelOutcome, Cluster, ClusterOpts, RequestState};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::engine::request::{EditError, EditRequest, EditRequestBuilder};
use instgenie::qos::Priority;
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::templates::RegisterAdmission;
use instgenie::util::json::Json;

/// Launch a 1-worker QoS cluster with slow denoise steps (forced cache
/// loads over a tiny simulated bandwidth), so preemption/expiry windows
/// are wide and deterministic.
fn launch_slow(tweak: impl FnOnce(&mut EngineConfig)) -> Option<Cluster> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model("sd21m").ok()?.config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 100;
    engine.max_batch = 1;
    // every block loads its cached rows over a 2 MiB/s copy stream:
    // ~tens of ms per denoise step, so a request is in flight for
    // hundreds of ms — a wide, reliable step-boundary window
    engine.force_all_cached = true;
    engine.sim_bandwidth = 2.0 * 1024.0 * 1024.0;
    tweak(&mut engine);
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched =
        scheduler::by_name("qos-aware", &mcfg, &lat, engine.cache_mode, engine.max_batch)
            .expect("scheduler");
    Some(
        Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .expect("launch"),
    )
}

fn edit(
    cluster: &Cluster,
    id: u64,
    seed: u64,
    ratio: f64,
    priority: Priority,
) -> EditRequest {
    let hw = cluster.model.latent_hw;
    EditRequestBuilder::new(id)
        .template("tpl-0")
        .prompt_seed(seed)
        .priority(priority)
        .synth_mask(hw, ratio)
        .expect("ratio")
        .build()
        .expect("valid request")
}

/// Block until the request is in the running batch.
fn await_running(cluster: &Cluster, id: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        match cluster.status(id).map(|s| s.state) {
            Some(RequestState::Running) => return,
            Some(RequestState::Queued) => {}
            other => panic!("request {id} left the queue unexpectedly: {other:?}"),
        }
        assert!(Instant::now() < deadline, "request {id} never started");
        std::thread::sleep(Duration::from_micros(200));
    }
}

#[test]
fn preempted_member_resumes_bit_identical_to_solo_run() {
    // solo reference: the same batch-class request, never preempted
    let Some(cluster) = launch_slow(|_| {}) else { return };
    let solo = cluster
        .submit_checked(edit(&cluster, 1, 77, 0.4, Priority::Batch))
        .expect("submit");
    let solo_resp = solo.wait(Duration::from_secs(120)).expect("solo run");
    cluster.shutdown().expect("shutdown");

    // preempted run: identical request, preempted by an interactive edit
    let Some(cluster) = launch_slow(|_| {}) else { return };
    let batch = cluster
        .submit_checked(edit(&cluster, 2, 77, 0.4, Priority::Batch))
        .expect("submit");
    await_running(&cluster, batch.id());
    let inter = cluster
        .submit_checked(edit(&cluster, 3, 5, 0.2, Priority::Interactive))
        .expect("submit");
    let inter_resp = inter.wait(Duration::from_secs(120)).expect("interactive");
    let batch_resp = batch.wait(Duration::from_secs(120)).expect("batch");
    cluster.shutdown().expect("shutdown");

    // the interactive request preempted the running batch member at a
    // step boundary (batch=1: there is no other way for it to start)
    assert!(
        batch_resp.timing.interruptions >= 1,
        "batch member was never preempted"
    );
    assert!(
        inter_resp.timing.e2e < batch_resp.timing.e2e,
        "interactive ({:.3}s) must finish before the preempted batch ({:.3}s)",
        inter_resp.timing.e2e,
        batch_resp.timing.e2e
    );
    // park + resume is numerically invisible: bit-identical output
    assert_eq!(solo_resp.latent.data(), batch_resp.latent.data());
    assert_eq!(solo_resp.image.data(), batch_resp.image.data());
    assert_eq!(solo_resp.timing.steps_computed, batch_resp.timing.steps_computed);
}

#[test]
fn deadline_expires_while_queued_without_wasting_steps() {
    let Some(cluster) = launch_slow(|_| {}) else { return };
    // blocker occupies the single batch slot for hundreds of ms
    let blocker = cluster
        .submit_checked(edit(&cluster, 10, 3, 0.4, Priority::Standard))
        .expect("submit");
    await_running(&cluster, blocker.id());
    // the victim's 30 ms deadline expires while it waits in the queue
    let mut victim_req = edit(&cluster, 11, 4, 0.2, Priority::Standard);
    victim_req.deadline = Some(victim_req.arrival + Duration::from_millis(30));
    let victim = cluster.submit_checked(victim_req).expect("submit");
    let err = victim.wait(Duration::from_secs(60)).expect_err("must expire");
    assert_eq!(err, EditError::DeadlineExceeded);
    assert_eq!(victim.status().unwrap().state.label(), "failed");
    // the expiry spent no denoise steps: the blocker still completes
    let resp = blocker.wait(Duration::from_secs(120)).expect("blocker");
    assert_eq!(resp.id, 10);
    cluster.shutdown().expect("shutdown");
}

#[test]
fn cancel_reaches_parked_requests() {
    let Some(cluster) = launch_slow(|_| {}) else { return };
    // a registration that never completes: submissions park at the worker
    assert!(matches!(
        cluster.template_registry().begin_register("tpl-parked"),
        RegisterAdmission::Started { .. }
    ));
    let hw = cluster.model.latent_hw;
    let req = EditRequestBuilder::new(20)
        .template("tpl-parked")
        .prompt_seed(9)
        .priority(Priority::Standard)
        .synth_mask(hw, 0.2)
        .unwrap()
        .build()
        .unwrap();
    let ticket = cluster.submit_checked(req).expect("registering accepts");
    // wait until the worker pops it off the queue into the parked set
    let deadline = Instant::now() + Duration::from_secs(30);
    while cluster.queue_depths()[0].queued > 0 {
        assert!(Instant::now() < deadline, "request never left the queue");
        std::thread::sleep(Duration::from_millis(1));
    }
    // DELETE on a parked request: cancel mark, resolved at the next
    // engine-loop boundary (Cancelled if we raced the pop instead)
    let outcome = cluster.cancel(ticket.id());
    assert!(
        matches!(outcome, CancelOutcome::Cancelling | CancelOutcome::Cancelled),
        "parked requests must be cancellable, got {outcome:?}"
    );
    let err = ticket.wait(Duration::from_secs(10)).expect_err("cancelled");
    assert_eq!(err, EditError::Cancelled);
    assert_eq!(ticket.status().unwrap().state.label(), "cancelled");
    cluster.shutdown().expect("shutdown");
}

#[test]
fn cancel_reaches_preempted_members() {
    let Some(cluster) = launch_slow(|_| {}) else { return };
    let batch = cluster
        .submit_checked(edit(&cluster, 30, 8, 0.4, Priority::Batch))
        .expect("submit");
    await_running(&cluster, batch.id());
    let inter = cluster
        .submit_checked(edit(&cluster, 31, 6, 0.2, Priority::Interactive))
        .expect("submit");
    // once the interactive request preempts the batch member, the batch
    // id becomes held — and cancellable — while still nominally running
    let deadline = Instant::now() + Duration::from_secs(60);
    let outcome = loop {
        match cluster.cancel(batch.id()) {
            CancelOutcome::TooLate => {
                assert!(
                    !batch
                        .status()
                        .map(|s| s.state.is_terminal())
                        .unwrap_or(true),
                    "batch finished before it could be preempted"
                );
                assert!(Instant::now() < deadline, "preemption never happened");
                std::thread::sleep(Duration::from_micros(200));
            }
            other => break other,
        }
    };
    assert_eq!(outcome, CancelOutcome::Cancelling);
    let err = batch.wait(Duration::from_secs(10)).expect_err("cancelled");
    assert_eq!(err, EditError::Cancelled);
    // the preempted slot was released: the interactive edit completes
    let resp = inter.wait(Duration::from_secs(120)).expect("interactive");
    assert_eq!(resp.id, 31);
    cluster.shutdown().expect("shutdown");
}

// -- HTTP-level admission + echo ---------------------------------------------

fn http(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn post(addr: &str, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn body_json(resp: &str) -> Json {
    Json::parse(resp.split("\r\n\r\n").nth(1).expect("body")).expect("json body")
}

fn serve(addr: &str, first_id: u64, tweak: impl FnOnce(&mut EngineConfig)) -> Option<Arc<HttpServer>> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model("sd21m").unwrap().config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 100;
    tweak(&mut engine);
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched =
        scheduler::by_name("qos-aware", &mcfg, &lat, engine.cache_mode, engine.max_batch)
            .unwrap();
    let cluster = Arc::new(
        Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .unwrap(),
    );
    let server = Arc::new(HttpServer::new(cluster, first_id));
    {
        let server = Arc::clone(&server);
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let _ = server.serve(&addr);
        });
    }
    std::thread::sleep(Duration::from_millis(100));
    Some(server)
}

#[test]
fn overloaded_submissions_get_429_with_retry_after() {
    // max_pending = 0: every submission is over capacity by definition
    let Some(server) = serve("127.0.0.1:18931", 100, |e| e.qos.max_pending = 0) else {
        return;
    };
    // route-level: typed error body with the retry estimate
    let (code, body) = server.route("POST", "/v1/edits", r#"{"template": "tpl-0"}"#);
    assert_eq!(code, 429, "{body}");
    assert_eq!(body.at("error_kind").as_str(), Some("overloaded"));
    assert!(body.at("retry_after_ms").as_f64().unwrap() > 0.0);
    // socket-level: the standard Retry-After header is set
    let resp = post("127.0.0.1:18931", "/v1/edits", r#"{"template": "tpl-0"}"#);
    assert!(resp.starts_with("HTTP/1.1 429"), "{resp}");
    assert!(resp.contains("\r\nRetry-After: "), "{resp}");
    // the sync wrapper sheds identically
    let resp = post("127.0.0.1:18931", "/edit", r#"{"template": "tpl-0"}"#);
    assert!(resp.starts_with("HTTP/1.1 429"), "{resp}");
}

#[test]
fn infeasible_deadlines_get_422_and_qos_fields_echo() {
    let Some(server) = serve("127.0.0.1:18932", 200, |_| {}) else { return };
    // a 1 ms deadline is infeasible against any real step estimate
    let (code, body) = server.route(
        "POST",
        "/v1/edits",
        r#"{"template": "tpl-0", "deadline_ms": 1}"#,
    );
    assert_eq!(code, 422, "{body}");
    assert_eq!(body.at("error_kind").as_str(), Some("deadline_infeasible"));
    // a zero deadline is rejected by the builder with the same kind
    let (code, body) = server.route(
        "POST",
        "/v1/edits",
        r#"{"template": "tpl-0", "deadline_ms": 0}"#,
    );
    assert_eq!(code, 422, "{body}");
    // unknown classes are a 400
    let (code, _) = server.route(
        "POST",
        "/v1/edits",
        r#"{"template": "tpl-0", "priority": "vip"}"#,
    );
    assert_eq!(code, 400);
    // a feasible submission echoes its class + deadline on every poll
    let (code, body) = server.route(
        "POST",
        "/v1/edits",
        r#"{"template": "tpl-0", "priority": "batch", "deadline_ms": 60000}"#,
    );
    assert_eq!(code, 202, "{body}");
    let id = body.at("id").as_usize().expect("id");
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (code, st) = server.route("GET", &format!("/v1/edits/{id}"), "");
        assert_eq!(code, 200);
        assert_eq!(st.at("priority").as_str(), Some("batch"));
        assert_eq!(st.at("deadline_ms").as_usize(), Some(60000));
        if st.at("status").as_str() == Some("done") {
            break;
        }
        assert!(Instant::now() < deadline, "edit never completed");
        std::thread::sleep(Duration::from_millis(5));
    }
    // per-class depths are visible in /v1/stats
    let (code, stats) = server.route("GET", "/v1/stats", "");
    assert_eq!(code, 200);
    let workers = stats.at("workers").as_arr().expect("workers");
    let classes = workers[0].at("classes");
    for p in Priority::ALL {
        assert!(
            classes.at(p.label()).at("queued").as_usize().is_some(),
            "missing class depth for {p:?}"
        );
    }
}
