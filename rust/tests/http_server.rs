//! Integration: the HTTP frontend routes edits through the cluster
//! (paper Fig. 8's user-facing path ① … ⑤ ) — covering the async v1
//! lifecycle endpoints (submit / poll / cancel), the synchronous `/edit`
//! wrapper (per-ticket, no cross-request rendezvous), oversized-body
//! rejection, and structured error mapping.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{BatchingPolicy, EngineConfig, SystemKind};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::util::json::Json;

fn http(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn post(addr: &str, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: &str, path: &str) -> String {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
}

fn delete(addr: &str, path: &str) -> String {
    http(addr, &format!("DELETE {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
}

fn body_json(resp: &str) -> Json {
    Json::parse(resp.split("\r\n\r\n").nth(1).expect("body")).expect("json body")
}

/// Launch cluster + HTTP server on `addr`; None when artifacts are absent.
fn serve(addr: &str, first_id: u64, tweak: impl FnOnce(&mut EngineConfig)) -> Option<Arc<HttpServer>> {
    let manifest = Manifest::load("artifacts").ok()?;
    let mcfg = manifest.model("sd21m").unwrap().config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 100;
    tweak(&mut engine);
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched =
        scheduler::by_name("mask-aware", &mcfg, &lat, engine.cache_mode, engine.max_batch)
            .unwrap();
    let cluster = Arc::new(
        Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into(), "tpl-1".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .unwrap(),
    );
    let server = Arc::new(HttpServer::new(cluster, first_id));
    {
        let server = Arc::clone(&server);
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let _ = server.serve(&addr);
        });
    }
    std::thread::sleep(Duration::from_millis(100));
    Some(server)
}

#[test]
fn edit_stats_healthz_round_trip() {
    let Some(server) = serve("127.0.0.1:18923", 1, |_| {}) else { return };
    let addr = "127.0.0.1:18923";
    // route() unit path (no sockets)
    let (code, body) = server.route("GET", "/healthz", "");
    assert_eq!(code, 200);
    assert_eq!(body.at("ok").as_bool(), Some(true));
    let (code, _) = server.route("GET", "/nope", "");
    assert_eq!(code, 404);
    let (code, body) = server.route("POST", "/edit", "{not json");
    assert_eq!(code, 400, "{body}");
    // typed validation errors surface before submission
    let (code, body) = server.route("POST", "/edit", r#"{"mask_ratio": 7.5}"#);
    assert_eq!(code, 400, "{body}");
    assert_eq!(body.at("error_kind").as_str(), Some("invalid_mask"));
    let (code, body) =
        server.route("POST", "/edit", r#"{"template": "no-such-template"}"#);
    assert_eq!(code, 404, "{body}");
    assert_eq!(body.at("error_kind").as_str(), Some("unknown_template"));

    // full socket path: synchronous wrapper returns this request's own
    // result with the timing decomposition
    let resp = post(addr, "/edit", r#"{"template": "tpl-0", "mask_ratio": 0.15, "prompt_seed": 7}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let j = body_json(&resp);
    assert_eq!(j.at("id").as_usize(), Some(1));
    assert_eq!(j.at("status").as_str(), Some("done"));
    assert!(j.at("timing").at("e2e").as_f64().unwrap() > 0.0);
    assert_eq!(j.at("timing").at("steps_computed").as_usize(), Some(8));

    let resp = get(addr, "/stats");
    assert!(resp.starts_with("HTTP/1.1 200"));
    let j = body_json(&resp);
    assert!(j.at("completed").as_usize().unwrap_or(0) >= 1);

    let resp = get(addr, "/v1/stats");
    let j = body_json(&resp);
    let workers = j.at("workers").as_arr().expect("workers array");
    assert_eq!(workers.len(), 1);
    assert!(workers[0].at("queued").as_usize().is_some());
    assert!(workers[0].at("outstanding").as_usize().is_some());
}

#[test]
fn v1_submit_poll_done_round_trip() {
    let Some(_server) = serve("127.0.0.1:18924", 100, |_| {}) else { return };
    let addr = "127.0.0.1:18924";

    let resp = post(addr, "/v1/edits", r#"{"template": "tpl-1", "mask_ratio": 0.2, "prompt_seed": 3}"#);
    assert!(resp.starts_with("HTTP/1.1 202"), "{resp}");
    let j = body_json(&resp);
    let id = j.at("id").as_usize().expect("id");
    assert_eq!(id, 100);
    assert_eq!(j.at("status").as_str(), Some("queued"));
    assert_eq!(j.at("status_url").as_str(), Some("/v1/edits/100"));

    // poll until done; every intermediate state must be a legal one
    let deadline = Instant::now() + Duration::from_secs(120);
    let done = loop {
        let j = body_json(&get(addr, &format!("/v1/edits/{id}")));
        match j.at("status").as_str() {
            Some("done") => break j,
            Some("queued") | Some("running") => {}
            other => panic!("unexpected status {other:?}"),
        }
        assert!(Instant::now() < deadline, "poll timed out");
        std::thread::sleep(Duration::from_millis(5));
    };
    // full per-request timing + image stats in the terminal state
    assert_eq!(done.at("template").as_str(), Some("tpl-1"));
    let t = done.at("timing");
    assert!(t.at("queue").as_f64().unwrap() >= 0.0);
    assert!(t.at("inference").as_f64().unwrap() > 0.0);
    assert!(t.at("e2e").as_f64().unwrap() > 0.0);
    assert_eq!(t.at("steps_computed").as_usize(), Some(8));
    assert!(done.at("image").at("rows").as_usize().unwrap() > 0);
    assert!(done.at("image").at("mean").as_f64().is_some());

    // unknown ids and malformed ids
    let resp = get(addr, "/v1/edits/999999");
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    let resp = get(addr, "/v1/edits/notanid");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
}

#[test]
fn v1_cancel_queued_request() {
    // inline batching with batch=1 keeps later submissions in the raw
    // queue for several inference rounds -> deterministic cancel window
    let Some(_server) = serve("127.0.0.1:18925", 500, |e| {
        e.batching = BatchingPolicy::ContinuousInline;
        e.max_batch = 1;
        // inline preprocess burns 20 ms per admission, widening the
        // window in which the tail request is still cancellable
        e.prepost_cpu_us = 20_000;
    }) else {
        return;
    };
    let addr = "127.0.0.1:18925";

    let mut ids = Vec::new();
    for seed in 0..4 {
        let resp = post(
            addr,
            "/v1/edits",
            &format!(r#"{{"template": "tpl-0", "mask_ratio": 0.1, "prompt_seed": {seed}}}"#),
        );
        assert!(resp.starts_with("HTTP/1.1 202"), "{resp}");
        ids.push(body_json(&resp).at("id").as_usize().unwrap());
    }
    // the last request cannot have been admitted yet (batch=1, FIFO)
    let victim = *ids.last().unwrap();
    let resp = delete(addr, &format!("/v1/edits/{victim}"));
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert_eq!(body_json(&resp).at("status").as_str(), Some("cancelled"));

    // cancelled is terminal + visible; a second DELETE evicts the entry
    let j = body_json(&get(addr, &format!("/v1/edits/{victim}")));
    assert_eq!(j.at("status").as_str(), Some("cancelled"));
    let resp = delete(addr, &format!("/v1/edits/{victim}"));
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert_eq!(body_json(&resp).at("status").as_str(), Some("evicted"));
    let resp = get(addr, &format!("/v1/edits/{victim}"));
    assert!(resp.starts_with("HTTP/1.1 404"), "evicted entries are gone: {resp}");
    let resp = delete(addr, &format!("/v1/edits/{victim}"));
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    let resp = delete(addr, "/v1/edits/424242");
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");

    // the surviving requests still complete
    let deadline = Instant::now() + Duration::from_secs(120);
    for id in &ids[..3] {
        loop {
            let j = body_json(&get(addr, &format!("/v1/edits/{id}")));
            if j.at("status").as_str() == Some("done") {
                break;
            }
            assert!(Instant::now() < deadline, "survivors never completed");
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

#[test]
fn v1_template_lifecycle_round_trip() {
    let Some(server) = serve("127.0.0.1:18928", 2000, |_| {}) else { return };
    let addr = "127.0.0.1:18928";

    // malformed registration bodies are rejected before touching state
    let (code, _) = server.route("POST", "/v1/templates", "{not json");
    assert_eq!(code, 400);
    let (code, _) = server.route("POST", "/v1/templates", r#"{"nope": 1}"#);
    assert_eq!(code, 400);
    let (code, _) = server.route("GET", "/v1/templates/absent", "");
    assert_eq!(code, 404);
    let (code, _) = server.route("DELETE", "/v1/templates/absent", "");
    assert_eq!(code, 404);

    // the launch set is listed as ready
    let j = body_json(&get(addr, "/v1/templates"));
    let listed = j.at("templates").as_arr().expect("templates array");
    assert!(listed.len() >= 2, "launch templates listed");
    assert!(listed.iter().all(|t| t.at("state").as_str() == Some("ready")));

    // online registration: accepted immediately, traced in the background
    let resp = post(addr, "/v1/templates", r#"{"template": "tpl-http"}"#);
    assert!(resp.starts_with("HTTP/1.1 202"), "{resp}");
    let j = body_json(&resp);
    assert_eq!(j.at("state").as_str(), Some("registering"));
    assert_eq!(j.at("status_url").as_str(), Some("/v1/templates/tpl-http"));

    // poll until ready; then every worker must hold it host-resident
    let deadline = Instant::now() + Duration::from_secs(120);
    let ready = loop {
        let j = body_json(&get(addr, "/v1/templates/tpl-http"));
        match j.at("state").as_str() {
            Some("ready") => break j,
            Some("registering") => {}
            other => panic!("unexpected template state {other:?}"),
        }
        assert!(Instant::now() < deadline, "registration never completed");
        std::thread::sleep(Duration::from_millis(5));
    };
    assert!(ready.at("bytes").as_usize().unwrap() > 0);
    let workers = ready.at("workers").as_arr().expect("residency per worker");
    assert!(!workers.is_empty());
    assert!(workers.iter().all(|w| w.at("residency").as_str() == Some("host")));

    // an edit against the online-registered template serves without restart
    let resp = post(
        addr,
        "/v1/edits",
        r#"{"template": "tpl-http", "mask_ratio": 0.15, "prompt_seed": 1}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 202"), "{resp}");
    let id = body_json(&resp).at("id").as_usize().unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let j = body_json(&get(addr, &format!("/v1/edits/{id}")));
        if j.at("status").as_str() == Some("done") {
            assert_eq!(j.at("template").as_str(), Some("tpl-http"));
            break;
        }
        assert!(Instant::now() < deadline, "edit never completed");
        std::thread::sleep(Duration::from_millis(5));
    }

    // registering an already-ready template is an idempotent 200
    let resp = post(addr, "/v1/templates", r#"{"template": "tpl-http"}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert_eq!(body_json(&resp).at("state").as_str(), Some("ready"));

    // tier stats are visible over HTTP, and the registered bytes are held
    let j = body_json(&get(addr, "/v1/stats"));
    let stats_workers = j.at("workers").as_arr().expect("workers");
    let cache = stats_workers[0].at("cache");
    for field in ["host_hits", "disk_promotions", "misses", "evictions"] {
        assert!(cache.at(field).as_usize().is_some(), "missing cache.{field}");
    }
    let bytes_before: usize = stats_workers
        .iter()
        .map(|w| w.at("cache").at("host_bytes").as_usize().unwrap())
        .sum();
    assert!(bytes_before > 0);

    // retirement: rejected edits, drained refs, freed bytes on every worker
    let resp = delete(addr, "/v1/templates/tpl-http");
    assert!(
        resp.starts_with("HTTP/1.1 200") || resp.starts_with("HTTP/1.1 202"),
        "{resp}"
    );
    let resp = post(
        addr,
        "/v1/edits",
        r#"{"template": "tpl-http", "mask_ratio": 0.15, "prompt_seed": 2}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 410"), "{resp}");
    assert_eq!(
        body_json(&resp).at("error_kind").as_str(),
        Some("template_retired")
    );
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let j = body_json(&get(addr, "/v1/templates/tpl-http"));
        assert_eq!(j.at("state").as_str(), Some("retired"));
        let workers = j.at("workers").as_arr().unwrap();
        if workers.iter().all(|w| w.at("residency").as_str() == Some("absent")) {
            break;
        }
        assert!(Instant::now() < deadline, "retired tiers never purged");
        std::thread::sleep(Duration::from_millis(5));
    }
    let j = body_json(&get(addr, "/v1/stats"));
    let bytes_after: usize = j
        .at("workers")
        .as_arr()
        .unwrap()
        .iter()
        .map(|w| w.at("cache").at("host_bytes").as_usize().unwrap())
        .sum();
    assert!(
        bytes_after < bytes_before,
        "DELETE must free host-tier bytes ({bytes_before} -> {bytes_after})"
    );
}

#[test]
fn oversized_body_yields_413() {
    let Some(_server) = serve("127.0.0.1:18926", 900, |_| {}) else { return };
    // declare 2 MiB: the server must refuse instead of truncating the read
    let resp = http(
        "127.0.0.1:18926",
        &format!(
            "POST /edit HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            2 << 20
        ),
    );
    assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
}

#[test]
fn concurrent_sync_edits_get_their_own_results() {
    // Regression for the global-rendezvous race: two concurrent POST
    // /edit used to block on "total completions grew", so one connection
    // could unblock on the *other* request's completion. With tickets,
    // each response carries its own id + full timing.
    let Some(_server) = serve("127.0.0.1:18927", 700, |_| {}) else { return };
    let addr = "127.0.0.1:18927";
    let spawn = |seed: u64| {
        let addr = addr.to_string();
        std::thread::spawn(move || {
            post(
                &addr,
                "/edit",
                &format!(r#"{{"template": "tpl-0", "mask_ratio": 0.12, "prompt_seed": {seed}}}"#),
            )
        })
    };
    let a = spawn(11);
    let b = spawn(22);
    let ra = a.join().unwrap();
    let rb = b.join().unwrap();
    let mut ids = Vec::new();
    for resp in [&ra, &rb] {
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        let j = body_json(resp);
        assert_eq!(j.at("status").as_str(), Some("done"));
        // a borrowed completion would miss this request's own timing
        assert_eq!(j.at("timing").at("steps_computed").as_usize(), Some(8));
        assert!(j.at("timing").at("e2e").as_f64().unwrap() > 0.0);
        ids.push(j.at("id").as_usize().unwrap());
    }
    assert_ne!(ids[0], ids[1], "each connection must resolve its own ticket");
}
