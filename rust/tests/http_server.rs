//! Integration: the HTTP frontend routes edits through the cluster
//! (paper Fig. 8's user-facing path ① … ⑤ ).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use instgenie::cache::LatencyModel;
use instgenie::cluster::{Cluster, ClusterOpts};
use instgenie::config::{EngineConfig, SystemKind};
use instgenie::runtime::Manifest;
use instgenie::scheduler;
use instgenie::server::HttpServer;
use instgenie::util::json::Json;

fn http(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn edit_stats_healthz_round_trip() {
    let Ok(manifest) = Manifest::load("artifacts") else { return };
    let mcfg = manifest.model("sd21m").unwrap().config.clone();
    let mut engine = EngineConfig::for_system(SystemKind::InstGenIE);
    engine.prepost_cpu_us = 100;
    let lat = LatencyModel::load_or_nominal("artifacts", "sd21m");
    let sched = scheduler::by_name("mask-aware", &mcfg, &lat, engine.cache_mode, 8).unwrap();
    let cluster = Arc::new(
        Cluster::launch(
            ClusterOpts {
                workers: 1,
                engine,
                model: "sd21m".into(),
                artifact_dir: "artifacts".into(),
                templates: vec!["tpl-0".into()],
                lat_model: lat,
                warmup: false,
            },
            sched,
        )
        .unwrap(),
    );
    let server = Arc::new(HttpServer::new(Arc::clone(&cluster), 1));
    // route() unit path (no sockets)
    let (code, body) = server.route("GET", "/healthz", "");
    assert_eq!(code, 200);
    assert_eq!(body.at("ok").as_bool(), Some(true));
    let (code, _) = server.route("GET", "/nope", "");
    assert_eq!(code, 404);
    let (code, body) = server.route("POST", "/edit", "{not json");
    assert_eq!(code, 400, "{body}");

    // full socket path
    let addr = "127.0.0.1:18923";
    {
        let server = Arc::clone(&server);
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let _ = server.serve(&addr);
        });
    }
    std::thread::sleep(Duration::from_millis(100));

    let body = r#"{"template": "tpl-0", "mask_ratio": 0.15, "prompt_seed": 7}"#;
    let req = format!(
        "POST /edit HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let resp = http(addr, &req);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
    let j = Json::parse(json_body).unwrap();
    assert_eq!(j.at("id").as_usize(), Some(1));

    let resp = http(addr, "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 200"));
    let j = Json::parse(resp.split("\r\n\r\n").nth(1).unwrap()).unwrap();
    assert!(j.at("completed").as_usize().unwrap_or(0) >= 1);
}
